//! Windowed time-series observability over the serving simulator.
//!
//! Whole-run aggregates hide transients: a flash crowd that blows a
//! model's SLO for ten seconds can vanish inside an end-of-run p99. This
//! sink replays the winning allocation's event log into fixed
//! simulated-nanosecond windows and reports, per window and per model,
//! nearest-rank p50/p95/p99, completions, goodput (completions meeting
//! the declared SLO), queue high-water, dispatched batches, and
//! per-share busy time — then runs a deterministic **SLO burn-rate
//! detector** over the window p99s (K-of-N trigger with hysteresis,
//! [`DriftConfig`]) whose [`DriftEvent`]s are the signal a future online
//! re-allocator will consume.
//!
//! Everything keys off the simulation's integer-nanosecond clock and the
//! replay log (itself bit-identical across `--threads` and repeat runs),
//! so the exported `scope-timeseries-v1` JSON and CSV artifacts are
//! byte-stable — `tests/timeseries.rs` pins this.

use crate::serve::{LogEntry, LogKind};
use crate::util::json::{arr, num, obj, s, Json};
use crate::util::stats::PercentileScratch;

/// Schema tag of the JSON export.
pub const SCHEMA: &str = "scope-timeseries-v1";

/// Ceiling on windows per run: an auto window targets [`AUTO_WINDOWS`],
/// and the CLI rejects an explicit `--window` that would slice the
/// horizon into more than this many (naming the flag) instead of
/// ballooning the export.
pub const MAX_WINDOWS: usize = 100_000;

/// Auto window count: `--window` unset divides the winner's makespan
/// into this many windows.
pub const AUTO_WINDOWS: u64 = 50;

/// K-of-N drift trigger: an SLO drift event opens when at least `k` of
/// the trailing `n` windows breach the model's declared p99 bound, and
/// clears (hysteresis) only when the trailing `n` windows are all clean.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct DriftConfig {
    pub k: usize,
    pub n: usize,
}

impl Default for DriftConfig {
    fn default() -> Self {
        DriftConfig { k: 3, n: 5 }
    }
}

impl DriftConfig {
    /// Parse the `--drift K/N` grammar (`3/5`): K breaching of the last
    /// N windows open an event. Errors name the offending token.
    pub fn parse(spec: &str) -> Result<DriftConfig, String> {
        let (k_s, n_s) = spec
            .split_once('/')
            .ok_or_else(|| format!("--drift: expected K/N (e.g. 3/5), got {spec:?}"))?;
        let parse = |what: &str, v: &str| -> Result<usize, String> {
            v.trim()
                .parse::<usize>()
                .map_err(|_| format!("--drift: {what} expects an integer, got {v:?}"))
        };
        let (k, n) = (parse("K", k_s)?, parse("N", n_s)?);
        if k == 0 {
            return Err(format!("--drift: K must be >= 1, got {spec:?}"));
        }
        if n < k {
            return Err(format!("--drift: N must be >= K, got {spec:?}"));
        }
        Ok(DriftConfig { k, n })
    }
}

/// Parse a `--window` duration to integer nanoseconds: a plain number is
/// milliseconds; `s`, `ms`, `us`, `ns` suffixes are accepted. Zero and
/// negative windows are rejected naming the flag.
pub fn parse_window(spec: &str) -> Result<u64, String> {
    let t = spec.trim();
    let (digits, scale) = if let Some(d) = t.strip_suffix("ms") {
        (d, 1e6)
    } else if let Some(d) = t.strip_suffix("us") {
        (d, 1e3)
    } else if let Some(d) = t.strip_suffix("ns") {
        (d, 1.0)
    } else if let Some(d) = t.strip_suffix('s') {
        (d, 1e9)
    } else {
        (t, 1e6) // bare number = milliseconds
    };
    let v: f64 = digits
        .trim()
        .parse()
        .map_err(|_| format!("--window: expects a duration (ms, or with s/ms/us/ns unit), got {spec:?}"))?;
    if !(v.is_finite() && v > 0.0) {
        return Err(format!("--window: must be a positive duration, got {spec:?}"));
    }
    let ns = (v * scale).round() as u64;
    if ns == 0 {
        return Err(format!("--window: {spec:?} rounds to 0 ns; windows must be positive"));
    }
    Ok(ns)
}

/// One model's statistics over one window.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct WindowModelStats {
    pub arrivals: u64,
    pub completions: u64,
    /// Completions whose end-to-end latency met the declared SLO
    /// (== `completions` for models without one).
    pub goodput: u64,
    /// Batches completed in this window.
    pub batches: u64,
    /// Deepest the model's queue got inside the window.
    pub queue_high_water: usize,
    pub p50_ns: u64,
    pub p95_ns: u64,
    pub p99_ns: u64,
    /// Declared SLO present, completions observed, and window p99 over
    /// the bound — the drift detector's per-window input.
    pub slo_breach: bool,
}

impl WindowModelStats {
    /// Mean requests per completed batch in the window (0 with none).
    pub fn batch_occupancy(&self) -> f64 {
        if self.batches == 0 {
            0.0
        } else {
            self.completions as f64 / self.batches as f64
        }
    }
}

/// One fixed simulated-ns window.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct Window {
    pub start_ns: u64,
    /// Busy nanoseconds per share (Dispatch→Complete spans clipped to
    /// the window).
    pub share_busy_ns: Vec<u64>,
    pub models: Vec<WindowModelStats>,
}

/// One SLO drift episode: the K-of-N trigger fired at `start_window` and
/// cleared (all trailing windows clean) at `clear_window`, or ran to the
/// end of the horizon (`None`).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct DriftEvent {
    pub model: usize,
    pub start_window: usize,
    pub clear_window: Option<usize>,
    /// Breaching windows inside the episode (trailing trigger span
    /// included).
    pub breach_windows: u64,
    pub worst_p99_ns: u64,
    pub slo_ns: u64,
}

/// The windowed time series of one serve run's winning allocation.
#[derive(Clone, Debug, PartialEq)]
pub struct TimeSeries {
    pub window_ns: u64,
    pub model_names: Vec<String>,
    pub slo_ns: Vec<Option<u64>>,
    pub shares: usize,
    pub windows: Vec<Window>,
    pub drift: DriftConfig,
    pub drift_events: Vec<DriftEvent>,
}

impl TimeSeries {
    /// Replay a winner's event log into fixed windows and run the drift
    /// detector. `window_ns = 0` picks the auto window (the makespan
    /// split into [`AUTO_WINDOWS`]). Pure function of its inputs: the
    /// log is already deterministic, so the result is bit-identical
    /// across threads and repeat runs.
    pub fn build(
        log: &[LogEntry],
        model_names: &[String],
        slo_ns: &[Option<u64>],
        shares: usize,
        makespan_ns: u64,
        window_ns: u64,
        drift: DriftConfig,
    ) -> TimeSeries {
        let span = makespan_ns.max(1);
        let window_ns = if window_ns == 0 { span.div_ceil(AUTO_WINDOWS).max(1) } else { window_ns };
        let count = ((span - 1) / window_ns + 1).min(MAX_WINDOWS as u64) as usize;
        let k = model_names.len();
        let mut windows: Vec<Window> = (0..count)
            .map(|w| Window {
                start_ns: w as u64 * window_ns,
                share_busy_ns: vec![0; shares],
                models: vec![WindowModelStats::default(); k],
            })
            .collect();
        // window index of a timestamp; the last window absorbs the tail
        // (only reachable when the MAX_WINDOWS clamp bit)
        let widx = |t: u64| ((t / window_ns) as usize).min(count - 1);
        // per-(window, model) latency samples, percentiled after the walk
        let mut lats: Vec<Vec<u64>> = vec![Vec::new(); count * k];
        // FIFO arrival times per model: queues are strictly FIFO, so the
        // n completions of a batch are exactly the n oldest arrivals
        let mut fifo: Vec<std::collections::VecDeque<u64>> =
            vec![std::collections::VecDeque::new(); k];
        // one open batch per share (a share serves one batch at a time)
        let mut open: Vec<Option<(u64, Vec<u64>)>> = vec![None; shares];
        for e in log {
            match e.kind {
                LogKind::Arrival => {
                    fifo[e.model].push_back(e.t_ns);
                    let stats = &mut windows[widx(e.t_ns)].models[e.model];
                    stats.arrivals += 1;
                    stats.queue_high_water = stats.queue_high_water.max(e.n);
                }
                LogKind::Dispatch => {
                    let batch: Vec<u64> =
                        (0..e.n).filter_map(|_| fifo[e.model].pop_front()).collect();
                    if let Some(slot) = open.get_mut(e.share) {
                        *slot = Some((e.t_ns, batch));
                    }
                }
                LogKind::Complete => {
                    let Some((t0, batch)) = open.get_mut(e.share).and_then(Option::take) else {
                        continue;
                    };
                    let w = widx(e.t_ns);
                    let stats = &mut windows[w].models[e.model];
                    stats.batches += 1;
                    for &a in &batch {
                        let lat = e.t_ns.saturating_sub(a);
                        stats.completions += 1;
                        if slo_ns[e.model].map(|slo| lat <= slo).unwrap_or(true) {
                            stats.goodput += 1;
                        }
                        lats[w * k + e.model].push(lat);
                    }
                    // split the busy span across the windows it covers
                    let (mut lo, hi) = (t0, e.t_ns);
                    while lo < hi {
                        let w = widx(lo);
                        let w_end = windows[w].start_ns.saturating_add(window_ns);
                        let edge = if w + 1 < count { hi.min(w_end) } else { hi };
                        windows[w].share_busy_ns[e.share] += edge - lo;
                        lo = edge;
                    }
                }
            }
        }
        let mut scratch = PercentileScratch::new();
        for (w, win) in windows.iter_mut().enumerate() {
            for (m, stats) in win.models.iter_mut().enumerate() {
                scratch.load(&lats[w * k + m]);
                stats.p50_ns = scratch.percentile(0.50);
                stats.p95_ns = scratch.percentile(0.95);
                stats.p99_ns = scratch.percentile(0.99);
                stats.slo_breach = stats.completions > 0
                    && slo_ns[m].map(|slo| stats.p99_ns > slo).unwrap_or(false);
            }
        }
        let mut ts = TimeSeries {
            window_ns,
            model_names: model_names.to_vec(),
            slo_ns: slo_ns.to_vec(),
            shares,
            windows,
            drift,
            drift_events: Vec::new(),
        };
        ts.drift_events = ts.detect_drift();
        ts
    }

    /// K-of-N burn-rate detection over the per-window breach flags, per
    /// model with a declared SLO. An event opens at the first window
    /// where ≥ K of the trailing N windows breach; hysteresis holds it
    /// open until the trailing N windows are all clean. Events sort by
    /// (start window, model) — deterministic.
    fn detect_drift(&self) -> Vec<DriftEvent> {
        let mut events = Vec::new();
        let DriftConfig { k, n } = self.drift;
        for (m, slo) in self.slo_ns.iter().enumerate() {
            let Some(slo) = *slo else { continue };
            let breach: Vec<bool> = self.windows.iter().map(|w| w.models[m].slo_breach).collect();
            let mut open: Option<DriftEvent> = None;
            for w in 0..breach.len() {
                let tail_start = w.saturating_sub(n - 1);
                let tail_breaches = breach[tail_start..=w].iter().filter(|&&b| b).count();
                match &mut open {
                    None if tail_breaches >= k => {
                        // fold the trailing windows that tripped the
                        // trigger into the event's stats
                        let mut ev = DriftEvent {
                            model: m,
                            start_window: w,
                            clear_window: None,
                            breach_windows: 0,
                            worst_p99_ns: 0,
                            slo_ns: slo,
                        };
                        for t in tail_start..=w {
                            if breach[t] {
                                ev.breach_windows += 1;
                                ev.worst_p99_ns =
                                    ev.worst_p99_ns.max(self.windows[t].models[m].p99_ns);
                            }
                        }
                        open = Some(ev);
                    }
                    Some(ev) if tail_breaches == 0 => {
                        ev.clear_window = Some(w);
                        events.push(open.take().unwrap());
                    }
                    Some(ev) => {
                        if breach[w] && w > ev.start_window {
                            ev.breach_windows += 1;
                            ev.worst_p99_ns =
                                ev.worst_p99_ns.max(self.windows[w].models[m].p99_ns);
                        }
                    }
                    None => {}
                }
            }
            if let Some(ev) = open {
                events.push(ev); // still burning at the end of the run
            }
        }
        events.sort_by_key(|e| (e.start_window, e.model));
        events
    }

    /// Simulated time (ns) at which an event's trigger window closed —
    /// where its Chrome-trace instant lands.
    pub fn trigger_ns(&self, ev: &DriftEvent) -> u64 {
        (ev.start_window as u64 + 1) * self.window_ns
    }

    /// The one-line end-of-run summary (`slo drift: ...`) the CLI prints
    /// and CI greps for.
    pub fn summary_line(&self) -> String {
        format!(
            "slo drift: {} event{} (window {:.3} ms, trigger {}-of-{})",
            self.drift_events.len(),
            if self.drift_events.len() == 1 { "" } else { "s" },
            self.window_ns as f64 / 1e6,
            self.drift.k,
            self.drift.n,
        )
    }

    /// The versioned `scope-timeseries-v1` JSON document.
    pub fn to_json(&self) -> Json {
        let series = self
            .windows
            .iter()
            .enumerate()
            .map(|(w, win)| {
                let models = win
                    .models
                    .iter()
                    .enumerate()
                    .map(|(m, st)| {
                        obj(vec![
                            ("model", s(&self.model_names[m])),
                            ("arrivals", num(st.arrivals as f64)),
                            ("completions", num(st.completions as f64)),
                            ("goodput", num(st.goodput as f64)),
                            ("batches", num(st.batches as f64)),
                            ("queue_high_water", num(st.queue_high_water as f64)),
                            ("p50_ns", num(st.p50_ns as f64)),
                            ("p95_ns", num(st.p95_ns as f64)),
                            ("p99_ns", num(st.p99_ns as f64)),
                            ("slo_breach", Json::Bool(st.slo_breach)),
                        ])
                    })
                    .collect();
                obj(vec![
                    ("window", num(w as f64)),
                    ("start_ns", num(win.start_ns as f64)),
                    (
                        "share_busy_ns",
                        arr(win.share_busy_ns.iter().map(|&b| num(b as f64)).collect()),
                    ),
                    ("models", arr(models)),
                ])
            })
            .collect();
        let drift_events = self
            .drift_events
            .iter()
            .map(|e| {
                obj(vec![
                    ("model", s(&self.model_names[e.model])),
                    ("start_window", num(e.start_window as f64)),
                    (
                        "clear_window",
                        e.clear_window.map(|w| num(w as f64)).unwrap_or(Json::Null),
                    ),
                    ("breach_windows", num(e.breach_windows as f64)),
                    ("worst_p99_ns", num(e.worst_p99_ns as f64)),
                    ("slo_ns", num(e.slo_ns as f64)),
                ])
            })
            .collect();
        obj(vec![
            ("schema", s(SCHEMA)),
            ("window_ns", num(self.window_ns as f64)),
            ("windows", num(self.windows.len() as f64)),
            ("shares", num(self.shares as f64)),
            ("models", arr(self.model_names.iter().map(|n| s(n)).collect())),
            (
                "slo_ns",
                arr(self
                    .slo_ns
                    .iter()
                    .map(|s| s.map(|v| num(v as f64)).unwrap_or(Json::Null))
                    .collect()),
            ),
            (
                "drift_trigger",
                obj(vec![("k", num(self.drift.k as f64)), ("n", num(self.drift.n as f64))]),
            ),
            ("series", arr(series)),
            ("drift_events", arr(drift_events)),
        ])
    }

    /// Long-format CSV twin of the JSON export: one `kind=model` row per
    /// (window, model) with the windowed stats, one `kind=share` row per
    /// (window, share) with busy nanoseconds.
    pub fn to_csv(&self) -> String {
        let mut out = String::from(
            "window,start_ns,kind,name,arrivals,completions,goodput,batches,\
             queue_high_water,p50_ns,p95_ns,p99_ns,slo_breach,busy_ns\n",
        );
        use std::fmt::Write as _;
        for (w, win) in self.windows.iter().enumerate() {
            for (m, st) in win.models.iter().enumerate() {
                let _ = writeln!(
                    out,
                    "{w},{},model,{},{},{},{},{},{},{},{},{},{},",
                    win.start_ns,
                    self.model_names[m],
                    st.arrivals,
                    st.completions,
                    st.goodput,
                    st.batches,
                    st.queue_high_water,
                    st.p50_ns,
                    st.p95_ns,
                    st.p99_ns,
                    st.slo_breach as u8,
                );
            }
            for (g, busy) in win.share_busy_ns.iter().enumerate() {
                let _ = writeln!(out, "{w},{},share,share{g},,,,,,,,,,{busy}", win.start_ns);
            }
        }
        out
    }

    /// Worst per-window p99 (ns) over all models and windows — the bench
    /// headline (`serving_windowed_p99_worst_ms`).
    pub fn worst_window_p99_ns(&self) -> u64 {
        self.windows
            .iter()
            .flat_map(|w| w.models.iter().map(|m| m.p99_ns))
            .max()
            .unwrap_or(0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serve::LogKind::{Arrival, Complete, Dispatch};

    fn entry(t_ns: u64, kind: LogKind, model: usize, share: usize, n: usize) -> LogEntry {
        LogEntry { t_ns, kind, model, share, n }
    }

    fn names(k: usize) -> Vec<String> {
        (0..k).map(|i| format!("m{i}")).collect()
    }

    #[test]
    fn windows_accumulate_latencies_goodput_and_busy_time() {
        // one model, one share, window 100 ns: a fast batch in window 0,
        // a slow SLO-blowing batch in window 1
        let log = vec![
            entry(0, Arrival, 0, 0, 1),
            entry(0, Dispatch, 0, 0, 1),
            entry(40, Complete, 0, 0, 1), // latency 40, within slo 50
            entry(100, Arrival, 0, 0, 1),
            entry(110, Arrival, 0, 0, 2),
            entry(110, Dispatch, 0, 0, 2),
            entry(260, Complete, 0, 0, 2), // latencies 160 and 150: breach
        ];
        let ts =
            TimeSeries::build(&log, &names(1), &[Some(50)], 1, 260, 100, DriftConfig::default());
        assert_eq!(ts.window_ns, 100);
        assert_eq!(ts.windows.len(), 3);
        let w0 = &ts.windows[0].models[0];
        assert_eq!((w0.arrivals, w0.completions, w0.goodput, w0.batches), (1, 1, 1, 1));
        assert_eq!(w0.p99_ns, 40);
        assert!(!w0.slo_breach);
        let w1 = &ts.windows[1].models[0];
        assert_eq!((w1.arrivals, w1.completions, w1.goodput), (2, 0, 0));
        assert_eq!(w1.queue_high_water, 2);
        let w2 = &ts.windows[2].models[0];
        // the batch completes at 260: both latencies land in window 2
        assert_eq!((w2.completions, w2.goodput, w2.batches), (2, 0, 1));
        assert_eq!(w2.p50_ns, 150);
        assert_eq!(w2.p99_ns, 160);
        assert!(w2.slo_breach);
        assert_eq!(w2.batch_occupancy(), 2.0);
        // busy time: [0,40) in w0; [110,260) splits 90 + 60
        assert_eq!(ts.windows[0].share_busy_ns[0], 40);
        assert_eq!(ts.windows[1].share_busy_ns[0], 90);
        assert_eq!(ts.windows[2].share_busy_ns[0], 60);
        assert_eq!(ts.worst_window_p99_ns(), 160);
        // identical inputs ⇒ identical series, exports included
        let again =
            TimeSeries::build(&log, &names(1), &[Some(50)], 1, 260, 100, DriftConfig::default());
        assert_eq!(ts, again);
        assert_eq!(ts.to_json().to_string_compact(), again.to_json().to_string_compact());
        assert_eq!(ts.to_csv(), again.to_csv());
    }

    /// A log with `breaches[w]` controlling whether window `w` (width
    /// 100 ns) breaches a 50 ns SLO.
    fn breach_log(breaches: &[bool]) -> Vec<LogEntry> {
        let mut log = Vec::new();
        for (w, &breach) in breaches.iter().enumerate() {
            let t0 = w as u64 * 100;
            let lat = if breach { 80 } else { 10 };
            log.push(entry(t0, Arrival, 0, 0, 1));
            log.push(entry(t0, Dispatch, 0, 0, 1));
            log.push(entry(t0 + lat, Complete, 0, 0, 1));
        }
        log
    }

    #[test]
    fn drift_triggers_k_of_n_with_hysteresis() {
        // windows: clean, then 3 breaches in 5 → trigger; clear only
        // after 5 clean windows
        let pattern = [
            false, true, false, true, true, // trigger at w4 (3 of last 5)
            false, true, false, false, false, // still open (w6 breach)
            false, false, false, false, false, // w10: last 5 clean → clear
            false,
        ];
        let makespan = pattern.len() as u64 * 100;
        let ts = TimeSeries::build(
            &breach_log(&pattern),
            &names(1),
            &[Some(50)],
            1,
            makespan,
            100,
            DriftConfig { k: 3, n: 5 },
        );
        assert_eq!(ts.drift_events.len(), 1, "{:?}", ts.drift_events);
        let ev = &ts.drift_events[0];
        assert_eq!(ev.model, 0);
        assert_eq!(ev.start_window, 4);
        assert_eq!(ev.clear_window, Some(11), "5 clean windows after w6 clear at w11");
        assert_eq!(ev.breach_windows, 4, "w1, w3, w4 from the trigger tail, then w6");
        assert_eq!(ev.worst_p99_ns, 80);
        assert_eq!(ev.slo_ns, 50);
        assert_eq!(ts.trigger_ns(ev), 500);
        assert!(ts.summary_line().contains("slo drift: 1 event ("), "{}", ts.summary_line());
        // an event still burning at the end stays open
        let open_ts = TimeSeries::build(
            &breach_log(&[false, true, true, true]),
            &names(1),
            &[Some(50)],
            1,
            400,
            100,
            DriftConfig { k: 3, n: 5 },
        );
        assert_eq!(open_ts.drift_events.len(), 1);
        assert_eq!(open_ts.drift_events[0].clear_window, None);
        // no SLO declared ⇒ no breaches, no events
        let calm = TimeSeries::build(
            &breach_log(&[true, true, true, true]),
            &names(1),
            &[None],
            1,
            400,
            100,
            DriftConfig { k: 3, n: 5 },
        );
        assert!(calm.drift_events.is_empty());
        assert!(calm.windows.iter().all(|w| !w.models[0].slo_breach));
    }

    #[test]
    fn auto_window_targets_auto_windows_and_exports_are_versioned() {
        let log = breach_log(&[true, false, true]);
        let ts = TimeSeries::build(&log, &names(1), &[Some(50)], 1, 300, 0, DriftConfig::default());
        assert_eq!(ts.window_ns, 6, "300 ns makespan / 50 auto windows");
        assert_eq!(ts.windows.len(), 50);
        let doc = ts.to_json();
        assert_eq!(doc.get("schema").unwrap().as_str().unwrap(), SCHEMA);
        assert_eq!(doc.get("windows").unwrap().as_f64().unwrap(), 50.0);
        assert_eq!(doc.get("series").unwrap().as_arr().unwrap().len(), 50);
        let csv = ts.to_csv();
        let lines: Vec<&str> = csv.lines().collect();
        assert!(lines[0].starts_with("window,start_ns,kind,name,"), "{}", lines[0]);
        // one model row + one share row per window, plus the header
        assert_eq!(lines.len(), 1 + 50 * 2);
        assert!(lines[1].contains(",model,m0,"));
        assert!(lines[2].contains(",share,share0,"));
    }

    #[test]
    fn drift_and_window_specs_name_the_offender() {
        assert_eq!(DriftConfig::parse("3/5"), Ok(DriftConfig { k: 3, n: 5 }));
        assert_eq!(DriftConfig::parse("1/1"), Ok(DriftConfig { k: 1, n: 1 }));
        for bad in ["", "3", "0/5", "5/3", "a/5", "3/b", "3:5"] {
            let err = DriftConfig::parse(bad).unwrap_err();
            assert!(err.contains("--drift"), "{bad:?}: {err}");
        }
        assert_eq!(parse_window("5"), Ok(5_000_000));
        assert_eq!(parse_window("5ms"), Ok(5_000_000));
        assert_eq!(parse_window("0.5s"), Ok(500_000_000));
        assert_eq!(parse_window("250us"), Ok(250_000));
        assert_eq!(parse_window("40ns"), Ok(40));
        for bad in ["0", "0ms", "-1", "soon", "", "0.0000001ns"] {
            let err = parse_window(bad).unwrap_err();
            assert!(err.contains("--window"), "{bad:?}: {err}");
        }
    }
}
