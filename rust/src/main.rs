//! `scope` — CLI for the Scope merged-pipeline framework.
//!
//! Subcommands (see `scope help`):
//!   info        network chain + workload stats
//!   search      run the Scope DSE on one (net, scale) and print the schedule
//!   compare     all four methods on one (net, scale)     [Fig. 7 cell]
//!   sweep       networks × scales table                  [Fig. 7]
//!   scaling     one network across scales                [Fig. 9]
//!   exhaustive  exhaustive-vs-search validation          [Fig. 8]
//!   casestudy   balance + energy breakdown               [Fig. 10]
//!   space       Equ. 8–9 search-space counts
//!   multi       co-schedule several models on one package [SCAR-style]
//!   serve       discrete-event serving sim: batching, SLOs, hybrid shares
//!   hetero      uniform-vs-heterogeneous package comparison
//!   pipeline    run the functional AOT pipeline (PJRT)   [E2E]

use anyhow::{anyhow, bail, Result};

use scope::arch::McmConfig;
use scope::baselines::{run_all, METHOD_NAMES};
use scope::config::{knob_table, validate_timeseries_out, Config, SimOptions};
use scope::coordinator::{run_pipeline, PipelineMode};
use scope::dse::{ExhaustiveOptions, PartitionSpace};
use scope::model::zoo;
use scope::model::WorkloadSet;
use scope::obs::timeseries::{parse_window, DriftConfig, MAX_WINDOWS};
use scope::pipeline::cache_store::CacheStore;
use scope::pipeline::ExecModeChoice;
use scope::report::figures;
use scope::runtime::Manifest;
use scope::scope::multi_model::parse_quantum;
use scope::scope::{co_schedule, schedule_scope, AllocatorKind, MultiOptions, SegmenterKind};
use scope::serve::trace::{RateSchedule, RequestStream};
use scope::serve::{self, ServeOptions};
use scope::util::cli::Args;
use scope::util::json::Json;
use scope::util::table::{eng, f3, Table};

const HELP: &str = "\
scope — merged pipeline framework for MCM NN accelerators (paper repro)

USAGE: scope <subcommand> [flags]

SUBCOMMANDS
  info        --net <name> [--chiplets C]   layer table; with a DAG also
              the condensation, and a fused-vs-pipeline per-segment table
              when --exec-mode auto is in effect
  search      --net <name> --chiplets <C> [--samples M]
  compare     --net <name> --chiplets <C> [--samples M]
  sweep       [--nets a,b,..] [--scales 16,64,256] [--samples M]
  scaling     [--net resnet50] [--scales 16,32,64,128,256] [--samples M]
              [--compare-segmenters]   adds a balanced-vs-dp Scope table
  exhaustive  [--net alexnet] [--chiplets 16] [--full-partitions] [--max-visits N]
  casestudy   [--net resnet152] [--chiplets 256] [--samples M]
  space       [--net resnet152] [--chiplets 256]
  multi       [--models a[:w],b,..] [--chiplets C] [--allocator dp|exhaustive]
              [--method scope] [--quantum Q]   co-schedule a serving set on
              one package vs the time-multiplexed sequential baseline
              (default set: resnet50_dag:1 + googlenet:2 + alexnet:4;
              the shared span/cluster cache store is on here by default)
  serve       [--models a[:w],b,.. | serving_mix] [--chiplets C] [--seed S]
              [--arrival-rate R | --trace file] [--rates a:r,..]
              [--rate-schedule spec|flash|diurnal] [--slo ms|a:ms,..]
              [--batch B] [--max-wait ms] [--horizon s] [--method scope]
              [--quantum Q] [--window dur] [--drift K/N]   replay a
              request stream against every hybrid spatial/temporal
              allocation of the share grid; batch latencies from the
              scheduled pipelines, temporal shares charged the DRAM
              weight-swap; allocations whose simulated p99 breaks a --slo
              bound are pruned. --rate-schedule drives non-stationary
              traffic (piecewise-constant '0s:1000,30s:5000,45s:1000', or
              the flash/diurnal presets scaled from --arrival-rate); the
              winner's replay folds into fixed --window slices of
              simulated time and a K-of-N SLO drift detector (--drift)
              flags windows whose p99 burns through a declared --slo.
              Deterministic: one seed = one bit-identical report.
  hetero      [--net resnet50] [--chiplets 16] [--specs 's1;s2;..'] [--samples M]
              schedule the same workload on a uniform package and on each
              heterogeneous spec, side by side (default specs: all-big,
              half big / half little, and the same mix with a slow
              cross-reticle column link). Specs separate on ';' or
              whitespace — a spec's own link list keeps its commas.
  pipeline    [--mode merged|isp|single|all] [--samples N] [--artifacts DIR]
  sensitivity [--net resnet50] [--chiplets 256] [--knob nop|dram]
  bench-diff  --old <baseline.json> --new <candidate.json>
              [--metric headline_speedup] [--fail-over 25]   compare two
              bench --json artifacts; errors when the headline metric
              regresses past the gate (metrics ending in _secs count as
              lower-is-better). A missing or \"provisional\" baseline
              records without gating.
  help

COMMON FLAGS
  --config <file>   key=value config file (see config/mod.rs)
  --samples <M>     pipeline batch size m (default 64)
  --threads <N>     DSE worker threads; 'auto' = one per core (default).
                    Results are bit-identical at every thread count.
  --segmenter <S>   segment allocator: 'balanced' (default) or 'dp'
                    (global boundary DP — never worse than balanced).
  --dp-window <W>   DP boundary window ±W domain steps around the balanced
                    seed (default 4; 0 = no prune, small nets only;
                    'auto' = re-widen whenever the optimum lands on the
                    window edge).
  --exec-mode <M>   per-segment execution: 'pipeline' (default, merged
                    pipeline), 'fused' (depth-first tile fusion, single
                    cluster per segment), or 'auto' (the DP picks the
                    cheaper mode per segment — never worse than pipeline).
  --tile-rows <R>   output rows per tile in the fused evaluator's tile
                    graph (default 4; must be >= 1).
  --prune <B>       branch-and-bound on admissible analytic lower bounds
                    (segment DP, share-split allocator, serving planner).
                    Default on; results are bit-identical either way —
                    '--prune off' forces every candidate through the
                    evaluator (the escape hatch / A-B baseline).
  --cache-store     process-wide keyed span/cluster cache: batched sweeps
                    pay each distinct span once (bit-identical results).
  --cache-file <f>  persist the cache store's span memos to <f> on exit and
                    reload them on startup (implies --cache-store unless
                    that flag explicitly disables the store): repeated
                    invocations reuse each other's sweeps — a warm run
                    re-schedules zero spans.
  --trace-out <f>   write a Chrome trace-event JSON of the run to <f> on
                    exit (open in Perfetto / chrome://tracing): simulated-
                    time Gantt of the winning schedule for 'search', per-
                    share batch service + arrivals for 'serve'. Simulated
                    timestamps make the file bit-identical at every
                    --threads setting.
  --metrics-out <f> write the metrics registry (span-memo hits, bounded-out
                    counts, serving tails, queue high-water, ...) to <f> on
                    exit: Prometheus text when <f> ends in .prom/.txt, a
                    stable JSON document otherwise.
  --trace-level <L> 'sim' (default): simulated-time events only, output
                    bit-identical across runs. 'full': also record wall-
                    clock DSE phase spans (where search time goes).
  --timeseries-out <f>  serve: write the winner's windowed time series on
                    exit as versioned scope-timeseries-v1 JSON plus a CSV
                    twin sharing the stem (<f> ends in .json or .csv).
                    Keyed off simulated ns: byte-identical at every
                    --threads setting and across repeat runs.
  --hetero <spec>   heterogeneous package: <class><count> runs filling the
                    zigzag mesh slots, plus optional /xcol<J>=<S>,xrow<J>=<S>
                    per-crossing NoP link scales — e.g. big8little8/xcol1=0.5.
                    Classes: big (the base chiplet), little (half the PE
                    array and global buffer, 0.7x MAC energy), micro (a
                    quarter, 0.55x). A single-class spec with unit links is
                    bit-identical to the plain uniform package.

`scope help` appends the full generated knob table (every config key,
CLI flag, and bench env var).

NETWORKS: alexnet vgg16 darknet19 resnet18/34/50/101/152 scopenet
          googlenet resnet18_dag resnet50_dag   (true multi-branch DAGs:
          segment boundaries restricted to clean cuts, skip/branch traffic
          crossing a boundary charged to DRAM)
";

fn net_flag(args: &Args, default: &str) -> Result<String> {
    let name = args.str_or("net", default);
    if zoo::by_name(&name).is_none() {
        bail!("unknown network {name:?}; options: {}", zoo::NAMES.join(" "));
    }
    Ok(name)
}

/// Load the config file (or the paper defaults) and fold the shared CLI
/// flags into `cfg.sim`. The full [`Config`] comes back so subcommands
/// can also read experiment-level keys (`models`).
fn load_config(args: &Args, chiplets: usize) -> Result<Config> {
    let mut cfg = match args.str_or("config", "").as_str() {
        "" => Config::paper_default(chiplets),
        path => Config::load_file(std::path::Path::new(path), chiplets)?,
    };
    let store_explicit = cache_store_explicit(args, &cfg);
    let sim = &mut cfg.sim;
    sim.samples = args.usize_or("samples", sim.samples as usize)? as u64;
    sim.threads = args.threads_or(sim.threads)?;
    // validated up front: unknown modes abort before any scheduling runs
    sim.segmenter = SegmenterKind::parse(&args.str_or("segmenter", sim.segmenter.name()))
        .map_err(|e| anyhow!("--segmenter: {e}"))?;
    match args.str_or("dp-window", "").as_str() {
        "" => {}
        "auto" => sim.dp_window_auto = true,
        v => {
            sim.dp_window = v
                .parse()
                .map_err(|_| anyhow!("--dp-window expects an integer or 'auto', got {v:?}"))?;
            sim.dp_window_auto = false;
        }
    }
    match args.str_or("exec-mode", "").as_str() {
        "" => {}
        v => {
            sim.exec_mode = ExecModeChoice::parse(v).map_err(|e| anyhow!("--exec-mode: {e}"))?;
        }
    }
    match args.str_or("tile-rows", "").as_str() {
        "" => {}
        v => {
            let rows: u64 = v
                .parse()
                .map_err(|_| anyhow!("--tile-rows expects a positive integer (>= 1), got {v:?}"))?;
            if rows == 0 {
                bail!("--tile-rows expects a positive integer (>= 1), got {v:?}");
            }
            sim.tile_rows = rows;
        }
    }
    match args.str_or("cache-store", "").as_str() {
        "" => {}
        "true" | "1" => sim.cache_store = true,
        "false" | "0" => sim.cache_store = false,
        other => bail!("--cache-store expects true/false, got {other:?}"),
    }
    match args.str_or("prune", "").as_str() {
        "" => {}
        "true" | "1" | "on" => sim.prune = true,
        "false" | "0" | "off" => sim.prune = false,
        other => bail!("--prune expects true/false, got {other:?}"),
    }
    match args.str_or("cache-file", "").as_str() {
        "" => {}
        path => {
            sim.cache_file = path.to_string();
            // --cache-file implies the store, but an explicit opt-out
            // wins whether it came from `--cache-store false` or a
            // `cache_store = false` config-file line
            if !store_explicit {
                sim.cache_store = true;
            }
        }
    }
    match args.str_or("trace-out", "").as_str() {
        "" => {}
        path => sim.trace_out = path.to_string(),
    }
    match args.str_or("metrics-out", "").as_str() {
        "" => {}
        path => sim.metrics_out = path.to_string(),
    }
    match args.str_or("timeseries-out", "").as_str() {
        "" => {}
        path => {
            // config-key errors say `timeseries_out`; rename to the flag
            validate_timeseries_out(path).map_err(|e| {
                anyhow!("--{}", e.to_string().replacen("timeseries_out", "timeseries-out", 1))
            })?;
            sim.timeseries_out = path.to_string();
        }
    }
    match args.str_or("trace-level", "").as_str() {
        "" => {}
        v => {
            sim.trace_level =
                scope::obs::TraceLevel::parse(v).map_err(|e| anyhow!("--trace-level: {e}"))?
        }
    }
    // arm the global trace sink / output paths before any scheduling runs
    scope::obs::configure(sim);
    if !sim.cache_file.is_empty() && sim.cache_store {
        let path = std::path::PathBuf::from(&sim.cache_file);
        // warm the process-wide store from disk; main() persists on exit.
        // An unreadable file must not brick the CLI — warn, start cold,
        // and let the exit-time persist rewrite it.
        if let Err(e) = CacheStore::global().load_file(&path) {
            eprintln!("warning: ignoring cache file {}: {e}", path.display());
        }
        CacheStore::global().set_persist_path(Some(path));
    }
    // applied last so the CLI wins over a config-file `hetero` key and the
    // class chips derive from the fully-overridden base chiplet
    match args.str_or("hetero", "").as_str() {
        "" => {}
        spec => scope::arch::apply_hetero(&mut cfg.mcm, spec).map_err(|e| anyhow!(e))?,
    }
    Ok(cfg)
}

/// Whether the user explicitly set the cache-store knob — via the CLI
/// flag or a config-file `cache_store` key. Explicit choices beat the
/// implied defaults of `--cache-file` and the batched subcommands.
fn cache_store_explicit(args: &Args, cfg: &Config) -> bool {
    !args.str_or("cache-store", "").is_empty() || cfg.cache_store_explicit
}

/// The batched subcommands (`multi`, `serve`) default the shared cache
/// store ON; an explicit opt-out wins, whether it came from the CLI flag
/// or a `cache_store = false` line in the config file.
fn batched_store_default(args: &Args, cfg: &Config, sim: &mut SimOptions) {
    if !cache_store_explicit(args, cfg) {
        sim.cache_store = true;
    }
}

fn sim_options(args: &Args, chiplets: usize) -> Result<(McmConfig, SimOptions)> {
    let cfg = load_config(args, chiplets)?;
    Ok((cfg.mcm, cfg.sim))
}

fn cmd_info(args: &Args) -> Result<()> {
    let name = net_flag(args, "resnet50")?;
    let net = zoo::by_name(&name).unwrap();
    let mut t = Table::new(
        &format!("{} — {} layers", net.name, net.len()),
        &["#", "layer", "type", "out(h×w×c)", "MACs", "weights", "branch"],
    );
    for (i, l) in net.layers.iter().enumerate() {
        let (h, w, c) = l.out_shape();
        t.row(vec![
            i.to_string(),
            l.name.clone(),
            format!("{:?}", l.kind),
            format!("{h}×{w}×{c}"),
            eng(l.macs() as f64),
            eng(l.weight_bytes() as f64),
            if l.branch { "yes" } else { "" }.into(),
        ]);
    }
    println!("{t}");
    println!(
        "total: {} MACs, {} weight bytes",
        eng(net.total_macs() as f64),
        eng(net.total_weight_bytes() as f64)
    );
    if net.dag.is_some() {
        println!();
        println!("{}", figures::dag_condensation_table(&net)?);
    }
    let chiplets = args.usize_or("chiplets", 16)?;
    let (_, sim) = sim_options(args, chiplets)?;
    if sim.exec_mode == ExecModeChoice::Auto {
        println!();
        println!("{}", figures::exec_mode_table(&name, chiplets, &sim)?);
    }
    Ok(())
}

fn cmd_search(args: &Args) -> Result<()> {
    let name = net_flag(args, "alexnet")?;
    let chiplets = args.usize_or("chiplets", 16)?;
    let (mcm, sim) = sim_options(args, chiplets)?;
    let net = zoo::by_name(&name).unwrap();
    let r = schedule_scope(&net, &mcm, &sim);
    match (&r.schedule, &r.eval.error) {
        (Some(sched), None) => {
            let mut t = Table::new(
                &format!("Scope schedule — {name} on {chiplets} chiplets"),
                &["segment", "cluster", "layers", "chiplets", "partitions", "mode"],
            );
            for (si, seg) in sched.segments.iter().enumerate() {
                for j in 0..seg.n_clusters() {
                    let (lo, hi) = seg.cluster_range(j);
                    let parts: String = (lo..hi)
                        .map(|k| match seg.partition(k) {
                            scope::pipeline::Partition::Wsp => 'W',
                            scope::pipeline::Partition::Isp => 'I',
                        })
                        .collect();
                    // on a mixed package, show which classes the region
                    // lands on; uniform output stays byte-identical
                    let mut chips = seg.regions[j].to_string();
                    if let Some(h) = mcm.hetero_classes() {
                        chips.push_str(&format!(
                            " [{}]",
                            h.label(seg.region_start(j), seg.regions[j])
                        ));
                    }
                    t.row(vec![
                        si.to_string(),
                        j.to_string(),
                        format!("[{lo},{hi})"),
                        chips,
                        parts,
                        seg.exec_mode.name().to_string(),
                    ]);
                }
            }
            println!("{t}");
            if let Some(h) = mcm.hetero_classes() {
                println!("package: {} ({})", h.spec(), h.label(0, mcm.chiplets));
            }
            scope::obs::class_busy_metrics(
                scope::obs::Registry::global(),
                &mcm,
                sched,
                &r.eval,
                sim.samples,
            );
            println!(
                "throughput: {} samples/s | energy: {} J/batch | cycles: {}",
                f3(r.throughput()),
                f3(r.eval.energy.total_pj() * 1e-12),
                eng(r.eval.total_cycles),
            );
            if let Some(rep) = &r.segmenter {
                let kind = match (rep.kind, rep.dp_window_auto) {
                    (SegmenterKind::Dp, true) if rep.dp_window == 0 => {
                        "dp (window auto → no prune)".to_string()
                    }
                    (SegmenterKind::Dp, true) => {
                        format!("dp (window auto → ±{})", rep.dp_window)
                    }
                    (SegmenterKind::Dp, false) => format!("dp (window ±{})", rep.dp_window),
                    (SegmenterKind::Balanced, _) => "balanced".to_string(),
                };
                println!(
                    "segmenter: {kind} | span cache: {} hits / {} misses ({:.0}% hit rate, {} cross-sweep)",
                    rep.stats.hits,
                    rep.stats.misses,
                    rep.stats.hit_rate() * 100.0,
                    rep.stats.cross_hits,
                );
            }
            // --trace-out: replay the winner into the global sink as a
            // simulated-time Gantt (no-op while tracing is off)
            scope::pipeline::timeline::trace_schedule(&net, &mcm, &sim, sched);
        }
        (_, err) => println!("no valid schedule: {err:?}"),
    }
    Ok(())
}

fn cmd_compare(args: &Args) -> Result<()> {
    let name = net_flag(args, "alexnet")?;
    let chiplets = args.usize_or("chiplets", 16)?;
    let (mcm, sim) = sim_options(args, chiplets)?;
    let net = zoo::by_name(&name).unwrap();
    let results = run_all(&net, &mcm, &sim);
    let best = results.iter().map(|r| r.throughput()).fold(0.0, f64::max);
    let mut t = Table::new(
        &format!("{name} on {chiplets} chiplets, m={}", sim.samples),
        &["method", "throughput (samples/s)", "normalized", "energy (J/batch)", "segments"],
    );
    for r in &results {
        t.row(vec![
            r.method.clone(),
            if r.eval.is_valid() { f3(r.throughput()) } else { "invalid".into() },
            if r.eval.is_valid() { f3(r.throughput() / best) } else { "-".into() },
            if r.eval.is_valid() {
                f3(r.eval.energy.total_pj() * 1e-12)
            } else {
                "-".into()
            },
            r.schedule
                .as_ref()
                .map(|s| s.segments.len().to_string())
                .unwrap_or_else(|| "-".into()),
        ]);
    }
    println!("{t}");
    Ok(())
}

fn cmd_sweep(args: &Args) -> Result<()> {
    let nets = args.str_or(
        "nets",
        "alexnet,vgg16,darknet19,resnet18,resnet34,resnet50,resnet101,resnet152",
    );
    let nets: Vec<&str> = nets.split(',').map(str::trim).collect();
    // Validate every name up front: a typo must not fail mid-sweep after
    // minutes of scheduling the networks before it.
    for n in &nets {
        if zoo::by_name(n).is_none() {
            bail!("unknown network {n:?} in --nets; options: {}", zoo::NAMES.join(" "));
        }
    }
    let scales = args.usize_list_or("scales", &[16, 64, 256])?;
    let (_, sim) = sim_options(args, scales.first().copied().unwrap_or(16))?;
    println!("{}", figures::fig7_opts(&nets, &scales, &sim)?);
    Ok(())
}

fn cmd_scaling(args: &Args) -> Result<()> {
    let name = net_flag(args, "resnet50")?;
    let scales = args.usize_list_or("scales", &[16, 32, 64, 128, 256])?;
    let (_, sim) = sim_options(args, scales.first().copied().unwrap_or(16))?;
    println!("{}", figures::fig9_opts(&name, &scales, &sim)?);
    if args.switch("compare-segmenters") {
        println!();
        println!("{}", figures::fig9_segmenter_compare(&name, &scales, &sim)?);
    }
    Ok(())
}

fn cmd_exhaustive(args: &Args) -> Result<()> {
    let name = net_flag(args, "alexnet")?;
    let chiplets = args.usize_or("chiplets", 16)?;
    let samples = args.usize_or("samples", 64)? as u64;
    let ex = ExhaustiveOptions {
        partition_space: if args.switch("full-partitions") {
            PartitionSpace::Full
        } else {
            PartitionSpace::Transitions
        },
        max_visits: args.usize_or("max-visits", 0)? as u64,
        ..Default::default()
    };
    let r = figures::fig8(&name, chiplets, samples, ex)?;
    println!("{}", r.table);
    println!("\nprocessing-time distribution (valid schedules):");
    for line in &r.hist_lines {
        println!("  {line}");
    }
    Ok(())
}

fn cmd_casestudy(args: &Args) -> Result<()> {
    let name = net_flag(args, "resnet152")?;
    let chiplets = args.usize_or("chiplets", 256)?;
    let samples = args.usize_or("samples", 64)? as u64;
    let r = figures::fig10(&name, chiplets, samples)?;
    println!("{}", r.balance);
    println!();
    println!("{}", r.energy);
    println!(
        "\nsegments: scope={} segmented={} | compute-balance CV: scope={} segmented={}",
        r.scope_segments,
        r.segmented_segments,
        f3(r.scope_cv),
        f3(r.segmented_cv)
    );
    Ok(())
}

fn cmd_space(args: &Args) -> Result<()> {
    let name = net_flag(args, "resnet152")?;
    let chiplets = args.usize_or("chiplets", 256)?;
    println!("{}", figures::space_table(&name, chiplets)?);
    Ok(())
}

/// The serving set of the `multi`/`serve` subcommands: `--models` wins,
/// then the config file's `models` key, then the built-in mix. Both
/// paths resolve the special name `serving_mix` through the same
/// [`WorkloadSet::resolve_pairs`] contract.
fn serving_set(args: &Args, cfg: &Config) -> Result<WorkloadSet> {
    let spec = args.str_or("models", "");
    if !spec.is_empty() {
        WorkloadSet::parse(&spec)
    } else if !cfg.models.is_empty() {
        WorkloadSet::resolve_pairs(&cfg.models)
    } else {
        Ok(WorkloadSet::serving_mix())
    }
}

fn cmd_multi(args: &Args) -> Result<()> {
    let chiplets = args.usize_or("chiplets", 64)?;
    let cfg = load_config(args, chiplets)?;
    let mut sim = cfg.sim.clone();
    batched_store_default(args, &cfg, &mut sim);
    let set = serving_set(args, &cfg)?;
    let mopts = MultiOptions {
        allocator: AllocatorKind::parse(&args.str_or("allocator", AllocatorKind::Dp.name()))
            .map_err(|e| anyhow!("--allocator: {e}"))?,
        method: args.str_choice_or("method", "scope", METHOD_NAMES)?,
        share_quantum: parse_quantum(&args.str_or("quantum", "auto"))
            .map_err(|e| anyhow!("--quantum: {e}"))?,
    };
    println!("serving set: {} on {} chiplets\n", set.label(), cfg.mcm.chiplets);
    let r = co_schedule(&set, &cfg.mcm, &sim, &mopts);
    println!("{}", figures::multi_model_table(&r)?);
    println!(
        "co-scheduled: {} mixes/s ({} samples/s aggregate) | time-multiplexed sequential: {} mixes/s ({} samples/s)",
        f3(r.rate),
        f3(r.total_throughput),
        f3(r.tm_rate),
        f3(r.tm_total),
    );
    match r.speedup_vs_tm() {
        Some(x) => println!(
            "co-schedule vs time-multiplexed: {:.3}x | allocator: {} ({} (model, share) evals, {} bounded out)",
            x,
            r.allocator.name(),
            r.evals,
            r.pruned_pairs
        ),
        None => println!(
            "allocator: {} ({} (model, share) evals, {} bounded out); baseline infeasible on the full package",
            r.allocator.name(),
            r.evals,
            r.pruned_pairs
        ),
    }
    if let Some(s) = &r.store {
        println!(
            "cache store: {} span sweeps ({} reused, {} spans carried) | shared cluster cache: {} hits / {} misses",
            s.span_checkouts, s.span_reuses, s.spans_carried, s.cluster_hits, s.cluster_misses,
        );
    }
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<()> {
    let chiplets = args.usize_or("chiplets", 16)?;
    let cfg = load_config(args, chiplets)?;
    let mut sim = cfg.sim.clone();
    batched_store_default(args, &cfg, &mut sim);
    let mut set = serving_set(args, &cfg)?;
    let slo_spec = args.str_or("slo", "");
    if !slo_spec.is_empty() {
        set.apply_slo_spec(&slo_spec).map_err(|e| anyhow!("--slo: {e}"))?;
    }
    let rates_spec = args.str_or("rates", "");
    if !rates_spec.is_empty() {
        set.apply_rate_spec(&rates_spec).map_err(|e| anyhow!("--rates: {e}"))?;
    }
    let sopts = ServeOptions {
        arrival_rate: args.f64_or("arrival-rate", 32.0)?,
        horizon_secs: args.f64_or("horizon", 0.25)?,
        max_batch: args.usize_or("batch", 8)?,
        max_wait_ms: args.f64_or("max-wait", 1.0)?,
        seed: args.usize_or("seed", 7)? as u64,
        method: args.str_choice_or("method", "scope", METHOD_NAMES)?,
        share_quantum: parse_quantum(&args.str_or("quantum", "auto"))
            .map_err(|e| anyhow!("--quantum: {e}"))?,
        rate_schedule: args.str_or("rate-schedule", ""),
        window_ns: match args.str_or("window", "").as_str() {
            "" | "auto" => 0,
            spec => parse_window(spec).map_err(|e| anyhow!("{e}"))?,
        },
        drift: match args.str_or("drift", "").as_str() {
            "" => DriftConfig::default(),
            spec => DriftConfig::parse(spec).map_err(|e| anyhow!("{e}"))?,
        },
    };
    let trace_path = args.str_or("trace", "");
    if !trace_path.is_empty() {
        // the trace determines every arrival — explicit stream-generation
        // flags would be silently ignored, so reject the conflict instead
        for flag in ["arrival-rate", "rates", "rate-schedule", "horizon", "seed"] {
            if !args.str_or(flag, "").is_empty() {
                bail!("--{flag} has no effect with --trace (the trace determines every arrival)");
            }
        }
    }
    // the full knob surface is validated before any scheduling runs
    sopts.validate(!trace_path.is_empty()).map_err(|e| anyhow!("{e}"))?;
    if trace_path.is_empty()
        && sopts.window_ns > 0
        && sopts.horizon_ns() / sopts.window_ns + 1 > MAX_WINDOWS as u64
    {
        bail!(
            "--window {spec} slices --horizon {h} s into more than {MAX_WINDOWS} windows; \
             widen the window or shorten the horizon",
            spec = args.str_or("window", ""),
            h = sopts.horizon_secs,
        );
    }
    let schedule = if trace_path.is_empty() && !sopts.rate_schedule.is_empty() {
        Some(RateSchedule::parse(&sopts.rate_schedule, sopts.arrival_rate, sopts.horizon_ns())?)
    } else {
        None
    };
    let stream = if !trace_path.is_empty() {
        RequestStream::load(std::path::Path::new(&trace_path), &set)?
    } else if let Some(schedule) = &schedule {
        let expected = serve::trace::expected_arrivals_scheduled(&set, schedule, sopts.horizon_ns());
        if expected > serve::trace::MAX_ARRIVALS as f64 {
            bail!(
                "--rate-schedule x --horizon would generate ~{expected:.0} requests (cap {}); \
                 lower the rates or shorten the horizon",
                serve::trace::MAX_ARRIVALS
            );
        }
        RequestStream::scheduled(&set, schedule, sopts.horizon_ns(), sopts.seed)
    } else {
        let expected =
            serve::trace::expected_arrivals(&set, sopts.arrival_rate, sopts.horizon_ns());
        if expected > serve::trace::MAX_ARRIVALS as f64 {
            bail!(
                "--arrival-rate/--rates x --horizon would generate ~{expected:.0} requests \
                 (cap {}); lower the rate or shorten the horizon",
                serve::trace::MAX_ARRIVALS
            );
        }
        RequestStream::poisson(&set, sopts.arrival_rate, sopts.horizon_ns(), sopts.seed)
    };
    let source = if !trace_path.is_empty() {
        format!("trace {trace_path}")
    } else if let Some(schedule) = &schedule {
        format!(
            "scheduled poisson {} over {} s, seed {}",
            schedule.label(),
            sopts.horizon_secs,
            sopts.seed
        )
    } else {
        format!(
            "poisson {} mix/s over {} s, seed {}",
            sopts.arrival_rate, sopts.horizon_secs, sopts.seed
        )
    };
    println!(
        "serving set: {} on {} chiplets | {} arrivals ({source})\n",
        set.label(),
        cfg.mcm.chiplets,
        stream.len(),
    );
    let r = serve::serve(&set, &cfg.mcm, &sim, &sopts, &stream);
    println!("{}", figures::serving_table(&r)?);
    for (mode, o) in r.modes() {
        let verdict = if !o.sim.feasible {
            "infeasible (a share cannot schedule its model)".to_string()
        } else if o.meets_all_slos {
            "meets every declared SLO".to_string()
        } else {
            format!("violates an SLO (worst p99/slo {:.2}x)", o.worst_slo_ratio)
        };
        println!(
            "{mode:>7} -> {} | {verdict} | {} swaps",
            o.alloc.label(&set),
            o.sim.swaps
        );
    }
    println!(
        "allocations: {} enumerated ({} bounded out, {} schedulable, {} meeting every SLO) | (model, share) evals: {}",
        r.allocations,
        r.pruned_allocations,
        r.feasible_allocations,
        r.slo_feasible_allocations,
        r.evals
    );
    let hybrid = r.hybrid.as_ref().ok_or_else(|| anyhow!("no allocation was enumerated"))?;
    println!(
        "completed: {} / {} requests on the winner | events: {} | makespan: {} ms",
        hybrid.sim.completed,
        stream.len(),
        hybrid.sim.events,
        f3(hybrid.sim.makespan_ns as f64 / 1e6),
    );
    if let Some(ts) = &r.timeseries {
        // the drift summary only means something against a declared SLO —
        // stdout of SLO-less runs stays byte-identical to earlier releases
        if set.models.iter().any(|m| m.slo_ns().is_some()) {
            println!("{}", ts.summary_line());
            if !ts.drift_events.is_empty() {
                println!("{}", figures::drift_table(&r)?);
            }
        }
        if !sim.timeseries_out.is_empty() {
            scope::obs::publish_timeseries(ts.to_json().to_string_compact() + "\n", ts.to_csv());
        }
    }
    Ok(())
}

fn cmd_hetero(args: &Args) -> Result<()> {
    let name = net_flag(args, "resnet50")?;
    let chiplets = args.usize_or("chiplets", 16)?;
    let (_, sim) = sim_options(args, chiplets)?;
    let specs = match args.str_or("specs", "").as_str() {
        "" => {
            // default comparison: all-big, an even big/little mix, and the
            // same mix with the first column crossing at half bandwidth
            let h = chiplets / 2;
            if chiplets >= 2 && chiplets % 2 == 0 {
                format!("big{chiplets};big{h}little{h};big{h}little{h}/xcol0=0.5")
            } else {
                format!("big{chiplets}")
            }
        }
        s => s.to_string(),
    };
    // ';' or whitespace separates specs — a spec's link list keeps its commas
    let specs: Vec<&str> = specs
        .split(|c: char| c == ';' || c.is_whitespace())
        .map(str::trim)
        .filter(|s| !s.is_empty())
        .collect();
    println!("{}", figures::hetero_table(&name, chiplets, &specs, &sim)?);
    Ok(())
}

fn cmd_pipeline(args: &Args) -> Result<()> {
    let dir = match args.str_or("artifacts", "").as_str() {
        "" => Manifest::default_dir(),
        p => p.into(),
    };
    let manifest = Manifest::load(&dir)?;
    let samples = args.usize_or("samples", 32)?;
    let modes: Vec<PipelineMode> = match args.str_or("mode", "all").as_str() {
        "merged" => vec![PipelineMode::Merged],
        "isp" => vec![PipelineMode::MergedIsp],
        "single" => vec![PipelineMode::Single],
        "all" => vec![PipelineMode::Single, PipelineMode::Merged, PipelineMode::MergedIsp],
        other => bail!("unknown mode {other:?} (merged|isp|single|all)"),
    };
    let mut t = Table::new(
        &format!("functional pipeline (PJRT CPU), {samples} samples"),
        &["mode", "stages", "throughput (samples/s)", "mean latency", "max |err| vs golden", "numerics"],
    );
    for mode in modes {
        let r = run_pipeline(&manifest, mode, samples)?;
        t.row(vec![
            r.mode.clone(),
            r.stages.to_string(),
            f3(r.throughput()),
            scope::bench::humanize_secs(r.mean_latency()),
            format!("{:.2e}", r.max_abs_err),
            if r.numerics_ok(1e-3) { "OK".into() } else { "FAIL".into() },
        ]);
    }
    println!("{t}");
    Ok(())
}

fn cmd_sensitivity(args: &Args) -> Result<()> {
    let name = net_flag(args, "resnet50")?;
    let chiplets = args.usize_or("chiplets", 256)?;
    let samples = args.usize_or("samples", 64)? as u64;
    let fracs = [1.0, 0.5, 0.25, 0.125, 0.0625];
    let sweep = match args.str_or("knob", "nop").as_str() {
        "nop" => scope::report::sensitivity::nop_bandwidth_sweep(&name, chiplets, samples, &fracs)?,
        "dram" => scope::report::sensitivity::dram_bandwidth_sweep(&name, chiplets, samples, &fracs)?,
        other => bail!("unknown knob {other:?} (nop|dram)"),
    };
    println!("{}", sweep.table);
    Ok(())
}

/// `scope bench-diff --old <baseline.json> --new <candidate.json>`:
/// compare two bench `--json` artifacts field by field and gate on the
/// headline metric. Metrics whose name ends in `_secs` are treated as
/// lower-is-better; everything else as higher-is-better. A missing
/// baseline, or one marked `"provisional": true`, records without
/// gating so the first real run on new hardware can seed the file.
fn cmd_bench_diff(args: &Args) -> Result<()> {
    let old_path = args.str_or("old", "");
    let new_path = args.str_or("new", "");
    if old_path.is_empty() || new_path.is_empty() {
        bail!("bench-diff needs --old <baseline.json> and --new <candidate.json>");
    }
    let metric = args.str_or("metric", "headline_speedup");
    let fail_over = args.f64_or("fail-over", 25.0)?;
    if !(fail_over >= 0.0) {
        bail!("--fail-over expects a non-negative percentage, got {fail_over}");
    }
    let old_text = match std::fs::read_to_string(&old_path) {
        Ok(text) => text,
        Err(_) => {
            println!("bench-diff: no baseline at {old_path}; recording only (no gate)");
            eprintln!(
                "bench-diff: WARNING: performance gating is DISARMED — no baseline file at \
                 {old_path}; seed it with `scope bench ... --json {old_path}` on the \
                 reference machine"
            );
            return Ok(());
        }
    };
    let new_text = std::fs::read_to_string(&new_path)
        .map_err(|e| anyhow!("reading --new {new_path}: {e}"))?;
    let old = Json::parse(&old_text).map_err(|e| anyhow!("parsing --old {old_path}: {e}"))?;
    let new = Json::parse(&new_text).map_err(|e| anyhow!("parsing --new {new_path}: {e}"))?;
    let (Json::Obj(old_map), Json::Obj(new_map)) = (&old, &new) else {
        bail!("bench artifacts must be JSON objects");
    };
    // Side-by-side table of every shared numeric top-level field.
    // BTreeMap iteration keeps the row order deterministic.
    let mut t = Table::new("bench-diff", &["metric", "old", "new", "delta"]);
    for (key, old_val) in old_map {
        let (Json::Num(o), Some(Json::Num(n))) = (old_val, new_map.get(key)) else {
            continue;
        };
        let delta = if *o != 0.0 {
            format!("{:+.1}%", (n - o) / o * 100.0)
        } else {
            "-".to_string()
        };
        t.row(vec![key.clone(), f3(*o), f3(*n), delta]);
    }
    println!("{t}");
    if matches!(old_map.get("provisional"), Some(Json::Bool(true))) {
        println!("bench-diff: baseline {old_path} is provisional; recording only (no gate)");
        eprintln!(
            "bench-diff: WARNING: performance gating is DISARMED — baseline {old_path} is \
             marked \"provisional\": true; arm the gate by re-recording it with \
             `scope bench ... --json {old_path}` on the reference machine (CI's bench-arm \
             step does this on main)"
        );
        return Ok(());
    }
    let o = old
        .get(&metric)
        .and_then(|j| j.as_f64())
        .map_err(|e| anyhow!("--old {old_path} metric {metric:?}: {e}"))?;
    let n = new
        .get(&metric)
        .and_then(|j| j.as_f64())
        .map_err(|e| anyhow!("--new {new_path} metric {metric:?}: {e}"))?;
    let lower_is_better = metric.ends_with("_secs");
    let regression_pct = if o > 0.0 {
        if lower_is_better {
            (n - o) / o * 100.0
        } else {
            (o - n) / o * 100.0
        }
    } else {
        0.0
    };
    if regression_pct > fail_over {
        bail!(
            "bench-diff: {metric} regressed {regression_pct:.1}% \
             ({o:.4} -> {n:.4}, gate {fail_over}%)"
        );
    }
    println!(
        "bench-diff: {metric} {o:.4} -> {n:.4} ({:+.1}% vs gate {fail_over}%) — ok",
        -regression_pct
    );
    Ok(())
}

fn main() -> Result<()> {
    let args = Args::from_env()?;
    let out = match args.subcommand.as_deref() {
        Some("info") => cmd_info(&args),
        Some("search") => cmd_search(&args),
        Some("compare") => cmd_compare(&args),
        Some("sweep") => cmd_sweep(&args),
        Some("scaling") => cmd_scaling(&args),
        Some("exhaustive") => cmd_exhaustive(&args),
        Some("casestudy") => cmd_casestudy(&args),
        Some("space") => cmd_space(&args),
        Some("multi") => cmd_multi(&args),
        Some("serve") => cmd_serve(&args),
        Some("hetero") => cmd_hetero(&args),
        Some("pipeline") => cmd_pipeline(&args),
        Some("sensitivity") => cmd_sensitivity(&args),
        Some("bench-diff") => cmd_bench_diff(&args),
        Some("help") | None => {
            print!("{HELP}");
            println!();
            println!("{}", knob_table());
            Ok(())
        }
        Some(other) => Err(anyhow!("unknown subcommand {other:?}; try `scope help`")),
    };
    // --cache-file: write the warmed span memos back for the next run —
    // even when the subcommand failed late, the spans it paid for are
    // pure values worth keeping (the subcommand's error still wins).
    let persisted = CacheStore::global().persist();
    if let Some(summary) = scope::obs::prune_audit_summary() {
        println!("{summary}");
    }
    let emitted = scope::obs::emit();
    out?;
    persisted?;
    emitted.map_err(|e| anyhow!("writing observability outputs: {e}"))?;
    Ok(())
}
