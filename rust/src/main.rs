//! `scope` — CLI for the Scope merged-pipeline framework.
//!
//! Subcommands (see `scope help`):
//!   info        network chain + workload stats
//!   search      run the Scope DSE on one (net, scale) and print the schedule
//!   compare     all four methods on one (net, scale)     [Fig. 7 cell]
//!   sweep       networks × scales table                  [Fig. 7]
//!   scaling     one network across scales                [Fig. 9]
//!   exhaustive  exhaustive-vs-search validation          [Fig. 8]
//!   casestudy   balance + energy breakdown               [Fig. 10]
//!   space       Equ. 8–9 search-space counts
//!   multi       co-schedule several models on one package [SCAR-style]
//!   pipeline    run the functional AOT pipeline (PJRT)   [E2E]

use anyhow::{anyhow, bail, Result};

use scope::arch::McmConfig;
use scope::baselines::{run_all, METHOD_NAMES};
use scope::config::{knob_table, Config, SimOptions};
use scope::coordinator::{run_pipeline, PipelineMode};
use scope::dse::{ExhaustiveOptions, PartitionSpace};
use scope::model::zoo;
use scope::model::WorkloadSet;
use scope::report::figures;
use scope::runtime::Manifest;
use scope::scope::{co_schedule, schedule_scope, AllocatorKind, MultiOptions, SegmenterKind};
use scope::util::cli::Args;
use scope::util::table::{eng, f3, Table};

const HELP: &str = "\
scope — merged pipeline framework for MCM NN accelerators (paper repro)

USAGE: scope <subcommand> [flags]

SUBCOMMANDS
  info        --net <name>
  search      --net <name> --chiplets <C> [--samples M]
  compare     --net <name> --chiplets <C> [--samples M]
  sweep       [--nets a,b,..] [--scales 16,64,256] [--samples M]
  scaling     [--net resnet50] [--scales 16,32,64,128,256] [--samples M]
              [--compare-segmenters]   adds a balanced-vs-dp Scope table
  exhaustive  [--net alexnet] [--chiplets 16] [--full-partitions] [--max-visits N]
  casestudy   [--net resnet152] [--chiplets 256] [--samples M]
  space       [--net resnet152] [--chiplets 256]
  multi       [--models a[:w],b,..] [--chiplets C] [--allocator dp|exhaustive]
              [--method scope] [--quantum Q]   co-schedule a serving set on
              one package vs the time-multiplexed sequential baseline
              (default set: resnet50_dag:1 + googlenet:2 + alexnet:4;
              the shared span/cluster cache store is on here by default)
  pipeline    [--mode merged|isp|single|all] [--samples N] [--artifacts DIR]
  sensitivity [--net resnet50] [--chiplets 256] [--knob nop|dram]
  help

COMMON FLAGS
  --config <file>   key=value config file (see config/mod.rs)
  --samples <M>     pipeline batch size m (default 64)
  --threads <N>     DSE worker threads; 'auto' = one per core (default).
                    Results are bit-identical at every thread count.
  --segmenter <S>   segment allocator: 'balanced' (default) or 'dp'
                    (global boundary DP — never worse than balanced).
  --dp-window <W>   DP boundary window ±W domain steps around the balanced
                    seed (default 4; 0 = no prune, small nets only;
                    'auto' = re-widen whenever the optimum lands on the
                    window edge).
  --cache-store     process-wide keyed span/cluster cache: batched sweeps
                    pay each distinct span once (bit-identical results).

`scope help` appends the full generated knob table (every config key,
CLI flag, and bench env var).

NETWORKS: alexnet vgg16 darknet19 resnet18/34/50/101/152 scopenet
          googlenet resnet18_dag resnet50_dag   (true multi-branch DAGs:
          segment boundaries restricted to clean cuts, skip/branch traffic
          crossing a boundary charged to DRAM)
";

fn net_flag(args: &Args, default: &str) -> Result<String> {
    let name = args.str_or("net", default);
    if zoo::by_name(&name).is_none() {
        bail!("unknown network {name:?}; options: {}", zoo::NAMES.join(" "));
    }
    Ok(name)
}

/// Load the config file (or the paper defaults) and fold the shared CLI
/// flags into `cfg.sim`. The full [`Config`] comes back so subcommands
/// can also read experiment-level keys (`models`).
fn load_config(args: &Args, chiplets: usize) -> Result<Config> {
    let mut cfg = match args.str_or("config", "").as_str() {
        "" => Config::paper_default(chiplets),
        path => Config::load_file(std::path::Path::new(path), chiplets)?,
    };
    let sim = &mut cfg.sim;
    sim.samples = args.usize_or("samples", sim.samples as usize)? as u64;
    sim.threads = args.threads_or(sim.threads)?;
    // validated up front: unknown modes abort before any scheduling runs
    sim.segmenter = SegmenterKind::parse(&args.str_or("segmenter", sim.segmenter.name()))
        .map_err(|e| anyhow!("--segmenter: {e}"))?;
    match args.str_or("dp-window", "").as_str() {
        "" => {}
        "auto" => sim.dp_window_auto = true,
        v => {
            sim.dp_window = v
                .parse()
                .map_err(|_| anyhow!("--dp-window expects an integer or 'auto', got {v:?}"))?;
            sim.dp_window_auto = false;
        }
    }
    match args.str_or("cache-store", "").as_str() {
        "" => {}
        "true" | "1" => sim.cache_store = true,
        "false" | "0" => sim.cache_store = false,
        other => bail!("--cache-store expects true/false, got {other:?}"),
    }
    Ok(cfg)
}

fn sim_options(args: &Args, chiplets: usize) -> Result<(McmConfig, SimOptions)> {
    let cfg = load_config(args, chiplets)?;
    Ok((cfg.mcm, cfg.sim))
}

fn cmd_info(args: &Args) -> Result<()> {
    let name = net_flag(args, "resnet50")?;
    let net = zoo::by_name(&name).unwrap();
    let mut t = Table::new(
        &format!("{} — {} layers", net.name, net.len()),
        &["#", "layer", "type", "out(h×w×c)", "MACs", "weights", "branch"],
    );
    for (i, l) in net.layers.iter().enumerate() {
        let (h, w, c) = l.out_shape();
        t.row(vec![
            i.to_string(),
            l.name.clone(),
            format!("{:?}", l.kind),
            format!("{h}×{w}×{c}"),
            eng(l.macs() as f64),
            eng(l.weight_bytes() as f64),
            if l.branch { "yes" } else { "" }.into(),
        ]);
    }
    println!("{t}");
    println!(
        "total: {} MACs, {} weight bytes",
        eng(net.total_macs() as f64),
        eng(net.total_weight_bytes() as f64)
    );
    if net.dag.is_some() {
        println!();
        println!("{}", figures::dag_condensation_table(&net)?);
    }
    Ok(())
}

fn cmd_search(args: &Args) -> Result<()> {
    let name = net_flag(args, "alexnet")?;
    let chiplets = args.usize_or("chiplets", 16)?;
    let (mcm, sim) = sim_options(args, chiplets)?;
    let net = zoo::by_name(&name).unwrap();
    let r = schedule_scope(&net, &mcm, &sim);
    match (&r.schedule, &r.eval.error) {
        (Some(sched), None) => {
            let mut t = Table::new(
                &format!("Scope schedule — {name} on {chiplets} chiplets"),
                &["segment", "cluster", "layers", "chiplets", "partitions"],
            );
            for (si, seg) in sched.segments.iter().enumerate() {
                for j in 0..seg.n_clusters() {
                    let (lo, hi) = seg.cluster_range(j);
                    let parts: String = (lo..hi)
                        .map(|k| match seg.partition(k) {
                            scope::pipeline::Partition::Wsp => 'W',
                            scope::pipeline::Partition::Isp => 'I',
                        })
                        .collect();
                    t.row(vec![
                        si.to_string(),
                        j.to_string(),
                        format!("[{lo},{hi})"),
                        seg.regions[j].to_string(),
                        parts,
                    ]);
                }
            }
            println!("{t}");
            println!(
                "throughput: {} samples/s | energy: {} J/batch | cycles: {}",
                f3(r.throughput()),
                f3(r.eval.energy.total_pj() * 1e-12),
                eng(r.eval.total_cycles),
            );
            if let Some(rep) = &r.segmenter {
                let kind = match (rep.kind, rep.dp_window_auto) {
                    (SegmenterKind::Dp, true) if rep.dp_window == 0 => {
                        "dp (window auto → no prune)".to_string()
                    }
                    (SegmenterKind::Dp, true) => {
                        format!("dp (window auto → ±{})", rep.dp_window)
                    }
                    (SegmenterKind::Dp, false) => format!("dp (window ±{})", rep.dp_window),
                    (SegmenterKind::Balanced, _) => "balanced".to_string(),
                };
                println!(
                    "segmenter: {kind} | span cache: {} hits / {} misses ({:.0}% hit rate, {} cross-sweep)",
                    rep.stats.hits,
                    rep.stats.misses,
                    rep.stats.hit_rate() * 100.0,
                    rep.stats.cross_hits,
                );
            }
        }
        (_, err) => println!("no valid schedule: {err:?}"),
    }
    Ok(())
}

fn cmd_compare(args: &Args) -> Result<()> {
    let name = net_flag(args, "alexnet")?;
    let chiplets = args.usize_or("chiplets", 16)?;
    let (mcm, sim) = sim_options(args, chiplets)?;
    let net = zoo::by_name(&name).unwrap();
    let results = run_all(&net, &mcm, &sim);
    let best = results.iter().map(|r| r.throughput()).fold(0.0, f64::max);
    let mut t = Table::new(
        &format!("{name} on {chiplets} chiplets, m={}", sim.samples),
        &["method", "throughput (samples/s)", "normalized", "energy (J/batch)", "segments"],
    );
    for r in &results {
        t.row(vec![
            r.method.clone(),
            if r.eval.is_valid() { f3(r.throughput()) } else { "invalid".into() },
            if r.eval.is_valid() { f3(r.throughput() / best) } else { "-".into() },
            if r.eval.is_valid() {
                f3(r.eval.energy.total_pj() * 1e-12)
            } else {
                "-".into()
            },
            r.schedule
                .as_ref()
                .map(|s| s.segments.len().to_string())
                .unwrap_or_else(|| "-".into()),
        ]);
    }
    println!("{t}");
    Ok(())
}

fn cmd_sweep(args: &Args) -> Result<()> {
    let nets = args.str_or(
        "nets",
        "alexnet,vgg16,darknet19,resnet18,resnet34,resnet50,resnet101,resnet152",
    );
    let nets: Vec<&str> = nets.split(',').map(str::trim).collect();
    // Validate every name up front: a typo must not fail mid-sweep after
    // minutes of scheduling the networks before it.
    for n in &nets {
        if zoo::by_name(n).is_none() {
            bail!("unknown network {n:?} in --nets; options: {}", zoo::NAMES.join(" "));
        }
    }
    let scales = args.usize_list_or("scales", &[16, 64, 256])?;
    let (_, sim) = sim_options(args, scales.first().copied().unwrap_or(16))?;
    println!("{}", figures::fig7_opts(&nets, &scales, &sim)?);
    Ok(())
}

fn cmd_scaling(args: &Args) -> Result<()> {
    let name = net_flag(args, "resnet50")?;
    let scales = args.usize_list_or("scales", &[16, 32, 64, 128, 256])?;
    let (_, sim) = sim_options(args, scales.first().copied().unwrap_or(16))?;
    println!("{}", figures::fig9_opts(&name, &scales, &sim)?);
    if args.switch("compare-segmenters") {
        println!();
        println!("{}", figures::fig9_segmenter_compare(&name, &scales, &sim)?);
    }
    Ok(())
}

fn cmd_exhaustive(args: &Args) -> Result<()> {
    let name = net_flag(args, "alexnet")?;
    let chiplets = args.usize_or("chiplets", 16)?;
    let samples = args.usize_or("samples", 64)? as u64;
    let ex = ExhaustiveOptions {
        partition_space: if args.switch("full-partitions") {
            PartitionSpace::Full
        } else {
            PartitionSpace::Transitions
        },
        max_visits: args.usize_or("max-visits", 0)? as u64,
        ..Default::default()
    };
    let r = figures::fig8(&name, chiplets, samples, ex)?;
    println!("{}", r.table);
    println!("\nprocessing-time distribution (valid schedules):");
    for line in &r.hist_lines {
        println!("  {line}");
    }
    Ok(())
}

fn cmd_casestudy(args: &Args) -> Result<()> {
    let name = net_flag(args, "resnet152")?;
    let chiplets = args.usize_or("chiplets", 256)?;
    let samples = args.usize_or("samples", 64)? as u64;
    let r = figures::fig10(&name, chiplets, samples)?;
    println!("{}", r.balance);
    println!();
    println!("{}", r.energy);
    println!(
        "\nsegments: scope={} segmented={} | compute-balance CV: scope={} segmented={}",
        r.scope_segments,
        r.segmented_segments,
        f3(r.scope_cv),
        f3(r.segmented_cv)
    );
    Ok(())
}

fn cmd_space(args: &Args) -> Result<()> {
    let name = net_flag(args, "resnet152")?;
    let chiplets = args.usize_or("chiplets", 256)?;
    println!("{}", figures::space_table(&name, chiplets)?);
    Ok(())
}

fn cmd_multi(args: &Args) -> Result<()> {
    let chiplets = args.usize_or("chiplets", 64)?;
    let cfg = load_config(args, chiplets)?;
    let mut sim = cfg.sim;
    // Batched by construction — the shared store defaults ON here, but an
    // explicit opt-out wins, whether it came from the CLI flag or a
    // `cache_store = false` line in the config file.
    let cli_set = !args.str_or("cache-store", "").is_empty();
    let cfg_set = match args.str_or("config", "").as_str() {
        "" => false,
        path => {
            // load_config already parsed this file successfully
            let text = std::fs::read_to_string(path)?;
            scope::config::parse_kv(&text)?.contains_key("cache_store")
        }
    };
    if !cli_set && !cfg_set {
        sim.cache_store = true;
    }
    let spec = args.str_or("models", "");
    let set = if !spec.is_empty() {
        WorkloadSet::parse(&spec)?
    } else if !cfg.models.is_empty() {
        WorkloadSet::from_pairs(&cfg.models)?
    } else {
        WorkloadSet::serving_mix()
    };
    let mopts = MultiOptions {
        allocator: AllocatorKind::parse(&args.str_or("allocator", AllocatorKind::Dp.name()))
            .map_err(|e| anyhow!("--allocator: {e}"))?,
        method: args.str_choice_or("method", "scope", METHOD_NAMES)?,
        share_quantum: args.usize_or("quantum", 0)?,
    };
    println!("serving set: {} on {} chiplets\n", set.label(), cfg.mcm.chiplets);
    let r = co_schedule(&set, &cfg.mcm, &sim, &mopts);
    println!("{}", figures::multi_model_table(&r)?);
    println!(
        "co-scheduled: {} mixes/s ({} samples/s aggregate) | time-multiplexed sequential: {} mixes/s ({} samples/s)",
        f3(r.rate),
        f3(r.total_throughput),
        f3(r.tm_rate),
        f3(r.tm_total),
    );
    match r.speedup_vs_tm() {
        Some(x) => println!(
            "co-schedule vs time-multiplexed: {:.3}x | allocator: {} ({} (model, share) evals)",
            x,
            r.allocator.name(),
            r.evals
        ),
        None => println!(
            "allocator: {} ({} (model, share) evals); baseline infeasible on the full package",
            r.allocator.name(),
            r.evals
        ),
    }
    if let Some(s) = &r.store {
        println!(
            "cache store: {} span sweeps ({} reused, {} spans carried) | shared cluster cache: {} hits / {} misses",
            s.span_checkouts, s.span_reuses, s.spans_carried, s.cluster_hits, s.cluster_misses,
        );
    }
    Ok(())
}

fn cmd_pipeline(args: &Args) -> Result<()> {
    let dir = match args.str_or("artifacts", "").as_str() {
        "" => Manifest::default_dir(),
        p => p.into(),
    };
    let manifest = Manifest::load(&dir)?;
    let samples = args.usize_or("samples", 32)?;
    let modes: Vec<PipelineMode> = match args.str_or("mode", "all").as_str() {
        "merged" => vec![PipelineMode::Merged],
        "isp" => vec![PipelineMode::MergedIsp],
        "single" => vec![PipelineMode::Single],
        "all" => vec![PipelineMode::Single, PipelineMode::Merged, PipelineMode::MergedIsp],
        other => bail!("unknown mode {other:?} (merged|isp|single|all)"),
    };
    let mut t = Table::new(
        &format!("functional pipeline (PJRT CPU), {samples} samples"),
        &["mode", "stages", "throughput (samples/s)", "mean latency", "max |err| vs golden", "numerics"],
    );
    for mode in modes {
        let r = run_pipeline(&manifest, mode, samples)?;
        t.row(vec![
            r.mode.clone(),
            r.stages.to_string(),
            f3(r.throughput()),
            scope::bench::humanize_secs(r.mean_latency()),
            format!("{:.2e}", r.max_abs_err),
            if r.numerics_ok(1e-3) { "OK".into() } else { "FAIL".into() },
        ]);
    }
    println!("{t}");
    Ok(())
}

fn cmd_sensitivity(args: &Args) -> Result<()> {
    let name = net_flag(args, "resnet50")?;
    let chiplets = args.usize_or("chiplets", 256)?;
    let samples = args.usize_or("samples", 64)? as u64;
    let fracs = [1.0, 0.5, 0.25, 0.125, 0.0625];
    let sweep = match args.str_or("knob", "nop").as_str() {
        "nop" => scope::report::sensitivity::nop_bandwidth_sweep(&name, chiplets, samples, &fracs)?,
        "dram" => scope::report::sensitivity::dram_bandwidth_sweep(&name, chiplets, samples, &fracs)?,
        other => bail!("unknown knob {other:?} (nop|dram)"),
    };
    println!("{}", sweep.table);
    Ok(())
}

fn main() -> Result<()> {
    let args = Args::from_env()?;
    match args.subcommand.as_deref() {
        Some("info") => cmd_info(&args),
        Some("search") => cmd_search(&args),
        Some("compare") => cmd_compare(&args),
        Some("sweep") => cmd_sweep(&args),
        Some("scaling") => cmd_scaling(&args),
        Some("exhaustive") => cmd_exhaustive(&args),
        Some("casestudy") => cmd_casestudy(&args),
        Some("space") => cmd_space(&args),
        Some("multi") => cmd_multi(&args),
        Some("pipeline") => cmd_pipeline(&args),
        Some("sensitivity") => cmd_sensitivity(&args),
        Some("help") | None => {
            print!("{HELP}");
            println!();
            println!("{}", knob_table());
            Ok(())
        }
        Some(other) => Err(anyhow!("unknown subcommand {other:?}; try `scope help`")),
    }
}
