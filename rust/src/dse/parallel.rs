//! Deterministic parallel execution for the DSE candidate sweeps.
//!
//! The search (`scope::search::search_segment`) and the exhaustive sweep
//! (`dse::exhaustive::exhaustive_segment`) both evaluate large numbers of
//! *independent* candidates; this module fans them across a
//! `std::thread::scope` worker pool with
//!
//! * a **sharded work queue** — one atomic cursor over the item list, so
//!   workers self-balance regardless of per-candidate cost skew, and
//! * an **ordered deterministic reduction** — results are reassembled in
//!   input order before any comparison happens, so the winning schedule is
//!   bit-identical to the serial sweep at every thread count.
//!
//! Determinism argument: every candidate evaluation is a pure function of
//! its input (the shared [`EvalCache`](crate::pipeline::eval_cache) only
//! memoizes those pure results), and all floating-point comparisons and
//! tie-breaks run *after* the ordered reduction, in the same order the
//! serial loop would visit them.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

/// Resolve a thread-count knob: `0` means one worker per available core.
pub fn resolve_threads(requested: usize) -> usize {
    if requested > 0 {
        requested
    } else {
        std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(1)
    }
}

/// Apply `f` to every item across `threads` scoped workers and return the
/// results **in input order**. `threads = 0` uses one worker per core;
/// `threads = 1` (or a single item) degenerates to the plain serial loop.
///
/// `f` receives `(index, item)` so callers can recover positional context
/// without capturing it in the item type.
pub fn par_map<T, R, F>(threads: usize, items: Vec<T>, f: F) -> Vec<R>
where
    T: Send,
    R: Send,
    F: Fn(usize, T) -> R + Sync,
{
    let n = items.len();
    let workers = resolve_threads(threads).min(n.max(1));
    if workers <= 1 {
        return items.into_iter().enumerate().map(|(i, t)| f(i, t)).collect();
    }
    // Sharded queue: items parked in per-slot cells, claimed via one
    // atomic cursor. Workers build local (index, result) runs and merge
    // once at the end, so the only contention is the cursor itself.
    let slots: Vec<Mutex<Option<T>>> =
        items.into_iter().map(|t| Mutex::new(Some(t))).collect();
    let cursor = AtomicUsize::new(0);
    let collected: Mutex<Vec<(usize, R)>> = Mutex::new(Vec::with_capacity(n));
    std::thread::scope(|s| {
        for _ in 0..workers {
            s.spawn(|| {
                let mut local: Vec<(usize, R)> = Vec::new();
                loop {
                    let i = cursor.fetch_add(1, Ordering::Relaxed);
                    if i >= n {
                        break;
                    }
                    let item = slots[i]
                        .lock()
                        .expect("queue slot poisoned")
                        .take()
                        .expect("slot claimed twice");
                    local.push((i, f(i, item)));
                }
                if !local.is_empty() {
                    collected.lock().expect("result sink poisoned").extend(local);
                }
            });
        }
    });
    let mut pairs = collected.into_inner().expect("result sink poisoned");
    debug_assert_eq!(pairs.len(), n);
    // Ordered reduction: identical visit order to the serial loop.
    pairs.sort_by_key(|&(i, _)| i);
    pairs.into_iter().map(|(_, r)| r).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn resolves_auto_to_at_least_one() {
        assert!(resolve_threads(0) >= 1);
        assert_eq!(resolve_threads(3), 3);
    }

    #[test]
    fn preserves_input_order_at_every_thread_count() {
        let items: Vec<usize> = (0..257).collect();
        let serial = par_map(1, items.clone(), |i, x| (i, x * 3));
        for t in [2usize, 4, 8] {
            let parallel = par_map(t, items.clone(), |i, x| (i, x * 3));
            assert_eq!(serial, parallel, "threads={t}");
        }
        for (i, &(j, v)) in serial.iter().enumerate() {
            assert_eq!(i, j);
            assert_eq!(v, i * 3);
        }
    }

    #[test]
    fn handles_empty_and_single_item() {
        let empty: Vec<u32> = vec![];
        assert!(par_map(4, empty, |_, x: u32| x).is_empty());
        assert_eq!(par_map(4, vec![7u32], |_, x| x + 1), vec![8]);
    }

    #[test]
    fn skewed_workloads_still_complete() {
        // Items with wildly different costs must all be processed once.
        let items: Vec<usize> = (0..64).collect();
        let out = par_map(8, items, |_, x| {
            let mut acc = 0u64;
            let spins = if x % 7 == 0 { 20_000 } else { 10 };
            for k in 0..spins {
                acc = acc.wrapping_add(k ^ x as u64);
            }
            std::hint::black_box(acc);
            x
        });
        assert_eq!(out, (0..64).collect::<Vec<_>>());
    }
}
