//! Exhaustive DSE over one segment — the Fig. 8 validation harness.
//!
//! Enumerates every (cluster composition, region composition, partition)
//! triple for a segment on `C` chiplets, evaluates each with the same
//! `Forward()` as the search algorithm, and reports the processing-time
//! distribution plus the exact rank of a given latency.
//!
//! Partition space: by default the `L+1` WSP→ISP transitions (the space
//! Scope actually searches); `PartitionSpace::Full` sweeps all `2^L`
//! masks — feasible for AlexNet-scale `L` (the paper also restricts the
//! exhaustive comparison to "the smallest-scale setting").
//!
//! When no visit cap is set, the (cluster, region) composition pairs fan
//! across the deterministic worker pool of [`super::parallel`] with
//! cluster evaluations memoized in a shared
//! [`EvalCache`](crate::pipeline::eval_cache::EvalCache); the reduction
//! runs in enumeration order, so `best_schedule`, `best_latency`, and the
//! latency population are bit-identical to the serial sweep. A nonzero
//! `max_visits` keeps the serial path (the cap is an inherently sequential
//! abort).

use crate::dse::parallel::{par_map, resolve_threads};
use crate::pipeline::eval_cache::{eval_segment_cached, EvalCache};
use crate::pipeline::schedule::{ExecMode, Partition, SegmentSchedule};
use crate::pipeline::timeline::EvalContext;
use crate::scope::partition::{mask_partitions, transition_partitions};
use crate::util::stats::Histogram;

/// Which per-layer partition assignments to enumerate.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PartitionSpace {
    /// The L+1 single-transition assignments.
    Transitions,
    /// All 2^L masks (L ≤ 24 guard).
    Full,
}

/// Aggregated outcome of an exhaustive sweep.
#[derive(Clone, Debug)]
pub struct ExhaustiveResult {
    /// Valid (capacity-respecting) configurations evaluated.
    pub valid: u64,
    /// Total configurations visited.
    pub visited: u64,
    /// Best latency found (cycles for the batch).
    pub best_latency: f64,
    pub best_schedule: Option<SegmentSchedule>,
    /// All valid latencies, for ranking (capped collection — see
    /// `ExhaustiveOptions::keep_latencies`).
    pub latencies: Vec<f64>,
}

/// Sweep controls.
#[derive(Clone, Copy, Debug)]
pub struct ExhaustiveOptions {
    pub partition_space: PartitionSpace,
    /// Stop after this many visited configs (0 = no cap).
    pub max_visits: u64,
    /// Keep at most this many latencies (reservoir-free prefix keep; the
    /// rank statistics use all of them when under the cap).
    pub keep_latencies: usize,
}

impl Default for ExhaustiveOptions {
    fn default() -> Self {
        ExhaustiveOptions {
            partition_space: PartitionSpace::Transitions,
            max_visits: 0,
            keep_latencies: 50_000_000,
        }
    }
}

/// Visit all compositions of `total` into `parts` positive ordered parts,
/// calling `f(&parts_vec)`; returns false if the visitor aborted.
fn for_each_composition<F: FnMut(&[usize]) -> bool>(total: usize, parts: usize, f: &mut F) -> bool {
    // iterative enumeration of split points via a stack of part sizes
    fn rec<F: FnMut(&[usize]) -> bool>(
        remaining: usize,
        parts_left: usize,
        acc: &mut Vec<usize>,
        f: &mut F,
    ) -> bool {
        if parts_left == 1 {
            acc.push(remaining);
            let go = f(acc);
            acc.pop();
            return go;
        }
        // leave at least 1 per remaining part
        for take in 1..=(remaining - (parts_left - 1)) {
            acc.push(take);
            let go = rec(remaining - take, parts_left - 1, acc, f);
            acc.pop();
            if !go {
                return false;
            }
        }
        true
    }
    if parts == 0 || parts > total {
        return true;
    }
    let mut acc = Vec::with_capacity(parts);
    rec(total, parts, &mut acc, f)
}

/// Per-task output of the parallel sweep: one (bounds, regions) pair
/// evaluated against every partition assignment.
struct TaskOut {
    visited: u64,
    valid: u64,
    latencies: Vec<f64>,
    best: Option<(f64, SegmentSchedule)>,
}

/// Run the exhaustive sweep over segment `[lo, hi)`.
pub fn exhaustive_segment(
    ctx: &EvalContext,
    lo: usize,
    hi: usize,
    m: u64,
    opts: ExhaustiveOptions,
) -> ExhaustiveResult {
    let l = hi - lo;
    let c = ctx.mcm.chiplets;
    assert!(l <= 24, "exhaustive sweep guard: L = {l} too deep");
    let partitions: Vec<Vec<Partition>> = match opts.partition_space {
        PartitionSpace::Transitions => {
            (0..=l).map(|idx| transition_partitions(l, idx)).collect()
        }
        PartitionSpace::Full => (0u64..(1 << l))
            .map(|mask| mask_partitions(l, mask))
            .collect(),
    };
    let cache = EvalCache::new();
    let mut res = ExhaustiveResult {
        valid: 0,
        visited: 0,
        best_latency: f64::INFINITY,
        best_schedule: None,
        latencies: Vec::new(),
    };

    if opts.max_visits == 0 && resolve_threads(ctx.opts.threads) > 1 {
        // ---- parallel path: one task per cluster composition (`bounds`);
        // each worker streams its region compositions × partitions with
        // O(1) extra memory, exactly as the serial loop would, and the
        // reduction runs in enumeration order — bit-identical results.
        // (Materializing (bounds, regions) pairs up front would allocate
        // the whole grid — millions of pairs at large C.)
        let mut tasks: Vec<Vec<usize>> = Vec::new();
        for n in 1..=l.min(c) {
            for_each_composition(l, n, &mut |layer_parts| {
                let mut bounds = Vec::with_capacity(n + 1);
                bounds.push(lo);
                for &p in layer_parts {
                    bounds.push(bounds.last().unwrap() + p);
                }
                tasks.push(bounds);
                true
            });
        }
        let outs: Vec<TaskOut> = par_map(ctx.opts.threads, tasks, |_, bounds| {
            let n = bounds.len() - 1;
            let mut out = TaskOut {
                visited: 0,
                valid: 0,
                latencies: Vec::new(),
                best: None,
            };
            for_each_composition(c, n, &mut |regions| {
                for parts in &partitions {
                    out.visited += 1;
                    let seg = SegmentSchedule {
                        lo,
                        hi,
                        bounds: bounds.clone(),
                        regions: regions.to_vec(),
                        partitions: parts.clone(),
                        exec_mode: ExecMode::Pipeline,
                    };
                    let ev = eval_segment_cached(ctx, &seg, m, Some(&cache));
                    if ev.error.is_some() {
                        continue;
                    }
                    let lat = ev.preload_cycles + ev.pipeline_cycles;
                    out.valid += 1;
                    // Per-task prefix cap: the ordered reduction only ever
                    // takes the first `keep_latencies` overall, and those
                    // come from each task's own prefix — so capping here
                    // bounds memory without changing the kept population.
                    if out.latencies.len() < opts.keep_latencies {
                        out.latencies.push(lat);
                    }
                    let better = out.best.as_ref().map(|b| lat < b.0).unwrap_or(true);
                    if better {
                        out.best = Some((lat, seg));
                    }
                }
                true
            });
            out
        });
        for out in outs {
            res.visited += out.visited;
            res.valid += out.valid;
            for lat in out.latencies {
                if res.latencies.len() < opts.keep_latencies {
                    res.latencies.push(lat);
                }
            }
            if let Some((lat, seg)) = out.best {
                if lat < res.best_latency {
                    res.best_latency = lat;
                    res.best_schedule = Some(seg);
                }
            }
        }
        return res;
    }

    // ---- serial path (also used whenever a visit cap is set: the cap is
    // an inherently sequential abort) ----
    // cluster compositions: layer counts per cluster, for every n
    for n in 1..=l.min(c) {
        let completed = for_each_composition(l, n, &mut |layer_parts| {
            // bounds from layer counts
            let mut bounds = Vec::with_capacity(n + 1);
            bounds.push(lo);
            for &p in layer_parts {
                bounds.push(bounds.last().unwrap() + p);
            }
            // region compositions; false propagates a visit-cap abort
            for_each_composition(c, n, &mut |regions| {
                for parts in &partitions {
                    res.visited += 1;
                    if opts.max_visits > 0 && res.visited > opts.max_visits {
                        return false;
                    }
                    let seg = SegmentSchedule {
                        lo,
                        hi,
                        bounds: bounds.clone(),
                        regions: regions.to_vec(),
                        partitions: parts.clone(),
                        exec_mode: ExecMode::Pipeline,
                    };
                    let ev = eval_segment_cached(ctx, &seg, m, Some(&cache));
                    if ev.error.is_some() {
                        continue;
                    }
                    let lat = ev.preload_cycles + ev.pipeline_cycles;
                    res.valid += 1;
                    if res.latencies.len() < opts.keep_latencies {
                        res.latencies.push(lat);
                    }
                    if lat < res.best_latency {
                        res.best_latency = lat;
                        res.best_schedule = Some(seg);
                    }
                }
                true
            })
        });
        if !completed {
            break;
        }
    }
    res
}

/// Exhaustively enumerate every segmentation of the chain `[0, l)` into
/// `min..=max` contiguous segments of ≤ `max_layers` layers each, with
/// span costs memoized (each distinct `(lo, hi)` costed once), and return
/// the best `(bounds, total)` — the ground truth the DP segmenter
/// ([`segment_dp`](crate::scope::segment_dp)) is validated against.
///
/// Totals accumulate left-to-right exactly like the DP's
/// `best[k-1][j] + cost(j, i)` recurrence, so for identical boundary
/// choices the two produce bit-identical sums. `span_cost` returning
/// `None` marks a span unschedulable; segmentations using it are skipped.
pub fn exhaustive_segmentations<F>(
    l: usize,
    min_segments: usize,
    max_segments: usize,
    max_layers: usize,
    mut span_cost: F,
) -> Option<(Vec<usize>, f64)>
where
    F: FnMut(usize, usize) -> Option<f64>,
{
    use std::collections::HashMap;
    let mut memo: HashMap<(usize, usize), Option<f64>> = HashMap::new();
    let mut best: Option<(Vec<usize>, f64)> = None;
    for s in min_segments.max(1)..=max_segments.min(l) {
        for_each_composition(l, s, &mut |parts| {
            if parts.iter().any(|&p| p > max_layers) {
                return true;
            }
            let mut bounds = Vec::with_capacity(s + 1);
            bounds.push(0usize);
            for &p in parts {
                bounds.push(bounds.last().unwrap() + p);
            }
            let mut total = 0.0f64;
            let mut ok = true;
            for w in bounds.windows(2) {
                let c = *memo
                    .entry((w[0], w[1]))
                    .or_insert_with(|| span_cost(w[0], w[1]));
                match c {
                    Some(c) => total += c,
                    None => {
                        ok = false;
                        break;
                    }
                }
            }
            if ok && best.as_ref().map(|b| total < b.1).unwrap_or(true) {
                best = Some((bounds, total));
            }
            true
        });
    }
    best
}

/// Exhaustively enumerate every segmentation of the chain `[0, l)` into
/// `min..=max` contiguous segments of ≤ `max_layers` layers each, **and**
/// every `[Pipeline, Fused]^k` execution-mode assignment over each
/// segmentation's `k` segments — the ground truth the per-segment mode
/// choice of the DP segmenter (`exec_mode=auto`) is validated against.
/// Returns the best `(bounds, modes, total)`.
///
/// Determinism mirrors the DP exactly: totals accumulate left-to-right,
/// improvements are strict (`<`), and mode masks ascend with Pipeline as
/// bit 0 — so among cost-tied assignments the all-lowest mask wins, which
/// is precisely "Fused only when strictly cheaper", the DP's per-span tie
/// rule. `span_cost` returning `None` marks a `(span, mode)` pair
/// unschedulable; assignments using it are skipped. Costs are memoized
/// per `(lo, hi, mode)`, each costed once.
pub fn exhaustive_mode_segmentations<F>(
    l: usize,
    min_segments: usize,
    max_segments: usize,
    max_layers: usize,
    mut span_cost: F,
) -> Option<(Vec<usize>, Vec<ExecMode>, f64)>
where
    F: FnMut(usize, usize, ExecMode) -> Option<f64>,
{
    use std::collections::HashMap;
    let mut memo: HashMap<(usize, usize, bool), Option<f64>> = HashMap::new();
    let mut best: Option<(Vec<usize>, Vec<ExecMode>, f64)> = None;
    for s in min_segments.max(1)..=max_segments.min(l) {
        for_each_composition(l, s, &mut |parts| {
            if parts.iter().any(|&p| p > max_layers) {
                return true;
            }
            let mut bounds = Vec::with_capacity(s + 1);
            bounds.push(0usize);
            for &p in parts {
                bounds.push(bounds.last().unwrap() + p);
            }
            // ascending masks: bit i = segment i fused. The argmin set is
            // a per-segment product, so the first (smallest) minimal mask
            // picks Pipeline wherever the two modes tie.
            for mask in 0u64..(1 << s) {
                let mut total = 0.0f64;
                let mut ok = true;
                for (i, w) in bounds.windows(2).enumerate() {
                    let fused = (mask >> i) & 1 == 1;
                    let mode = if fused {
                        ExecMode::Fused
                    } else {
                        ExecMode::Pipeline
                    };
                    let c = *memo
                        .entry((w[0], w[1], fused))
                        .or_insert_with(|| span_cost(w[0], w[1], mode));
                    match c {
                        Some(c) => total += c,
                        None => {
                            ok = false;
                            break;
                        }
                    }
                }
                if ok && best.as_ref().map(|b| total < b.2).unwrap_or(true) {
                    let modes = (0..s)
                        .map(|i| {
                            if (mask >> i) & 1 == 1 {
                                ExecMode::Fused
                            } else {
                                ExecMode::Pipeline
                            }
                        })
                        .collect();
                    best = Some((bounds.clone(), modes, total));
                }
            }
            true
        });
    }
    best
}

/// Exhaustively enumerate every segmentation of `[0, l)` whose internal
/// boundaries are drawn from the legal `cuts` (ascending positions in
/// `(0, l)`) — the DAG counterpart of [`exhaustive_segmentations`], and
/// the ground truth the branch-aware segmenter DP is validated against.
/// Boundary subsets are visited in lexicographic order per segment count;
/// totals accumulate left-to-right like the DP's recurrence, so identical
/// boundary choices produce bit-identical sums.
pub fn exhaustive_cut_segmentations<F>(
    l: usize,
    cuts: &[usize],
    min_segments: usize,
    max_segments: usize,
    max_layers: usize,
    mut span_cost: F,
) -> Option<(Vec<usize>, f64)>
where
    F: FnMut(usize, usize) -> Option<f64>,
{
    use std::collections::HashMap;
    debug_assert!(cuts.windows(2).all(|w| w[0] < w[1]));
    debug_assert!(cuts.iter().all(|&c| c > 0 && c < l));
    let mut memo: HashMap<(usize, usize), Option<f64>> = HashMap::new();
    let mut best: Option<(Vec<usize>, f64)> = None;
    let d = cuts.len();
    for s in min_segments.max(1)..=max_segments.min(l) {
        if s - 1 > d {
            continue;
        }
        // lexicographic choice of s−1 ascending cut indices
        let mut choice: Vec<usize> = (0..s - 1).collect();
        loop {
            let mut bounds = Vec::with_capacity(s + 1);
            bounds.push(0usize);
            bounds.extend(choice.iter().map(|&i| cuts[i]));
            bounds.push(l);
            if bounds.windows(2).all(|w| w[1] - w[0] <= max_layers) {
                let mut total = 0.0f64;
                let mut ok = true;
                for w in bounds.windows(2) {
                    let c = *memo
                        .entry((w[0], w[1]))
                        .or_insert_with(|| span_cost(w[0], w[1]));
                    match c {
                        Some(c) => total += c,
                        None => {
                            ok = false;
                            break;
                        }
                    }
                }
                if ok && best.as_ref().map(|b| total < b.1).unwrap_or(true) {
                    best = Some((bounds, total));
                }
            }
            // advance to the next lexicographic k-subset of 0..d
            let k = s - 1;
            if k == 0 {
                break;
            }
            let mut advanced = false;
            let mut i = k;
            while i > 0 {
                i -= 1;
                if choice[i] < d - k + i {
                    choice[i] += 1;
                    for t in i + 1..k {
                        choice[t] = choice[t - 1] + 1;
                    }
                    advanced = true;
                    break;
                }
            }
            if !advanced {
                break;
            }
        }
    }
    best
}

/// Visit every way to give each of `models` workloads one chiplet share
/// drawn from `sizes` (strictly ascending candidate share counts), with
/// the shares summing to at most `budget` — the multi-model chiplet-split
/// ground truth [`scope::multi_model`](crate::scope::multi_model)
/// validates its weighted-throughput DP against. Splits are visited in
/// lexicographic order (model 0's share varies slowest, each share
/// ascending), so "first wins" tie-breaking is deterministic. The
/// callback returns `false` to stop early; the function returns `false`
/// iff it was stopped.
pub fn for_each_share_split<F>(models: usize, sizes: &[usize], budget: usize, f: &mut F) -> bool
where
    F: FnMut(&[usize]) -> bool,
{
    fn rec<F: FnMut(&[usize]) -> bool>(
        cur: &mut Vec<usize>,
        models: usize,
        sizes: &[usize],
        left: usize,
        f: &mut F,
    ) -> bool {
        if cur.len() == models {
            return f(cur);
        }
        for &s in sizes {
            if s > left {
                break; // ascending sizes: nothing further fits
            }
            cur.push(s);
            let keep_going = rec(cur, models, sizes, left - s, f);
            cur.pop();
            if !keep_going {
                return false;
            }
        }
        true
    }
    if models == 0 {
        return true;
    }
    debug_assert!(sizes.windows(2).all(|w| w[0] < w[1]), "sizes must ascend");
    rec(&mut Vec::with_capacity(models), models, sizes, budget, f)
}

impl ExhaustiveResult {
    /// Fraction of valid schedules strictly better than `latency`
    /// (the paper's "top 0.05%" is `rank_of(scope_latency) ≤ 0.0005`).
    /// An empty population has no meaningful rank: returns `NaN` (which
    /// deliberately fails any `rank <= bound` assertion downstream).
    pub fn rank_of(&self, latency: f64) -> f64 {
        if self.latencies.is_empty() {
            return f64::NAN;
        }
        let better = self.latencies.iter().filter(|&&x| x < latency).count();
        better as f64 / self.latencies.len() as f64
    }

    /// Processing-time histogram over the valid population (Fig. 8's
    /// x-axis buckets). An empty population yields an empty histogram over
    /// a degenerate `[0, 1)` range rather than folding `±∞` bounds into
    /// `Histogram::new`.
    pub fn histogram(&self, bins: usize) -> Histogram {
        if self.latencies.is_empty() {
            return Histogram::new(0.0, 1.0, bins);
        }
        let lo = self.latencies.iter().copied().fold(f64::INFINITY, f64::min);
        let hi = self.latencies.iter().copied().fold(0.0f64, f64::max);
        let mut h = Histogram::new(lo, (hi * 1.0001).max(lo + 1.0), bins);
        for &x in &self.latencies {
            h.add(x);
        }
        h
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::McmConfig;
    use crate::config::SimOptions;
    use crate::model::zoo::scopenet;
    use crate::scope::{search_segment, SearchOptions};
    use crate::storage::StoragePolicy;

    fn count_compositions(total: usize, parts: usize) -> u64 {
        let mut n = 0u64;
        for_each_composition(total, parts, &mut |_| {
            n += 1;
            true
        });
        n
    }

    #[test]
    fn composition_counts_are_binomial() {
        // compositions of T into P parts = C(T−1, P−1)
        assert_eq!(count_compositions(5, 1), 1);
        assert_eq!(count_compositions(5, 2), 4);
        assert_eq!(count_compositions(5, 3), 6);
        assert_eq!(count_compositions(6, 6), 1);
        assert_eq!(count_compositions(3, 4), 0);
    }

    #[test]
    fn composition_visitor_aborts() {
        let mut seen = 0;
        for_each_composition(6, 2, &mut |_| {
            seen += 1;
            seen < 3
        });
        assert_eq!(seen, 3);
    }

    #[test]
    fn segmentation_enumeration_finds_known_optimum() {
        // cost = span² → splitting as evenly and as often as allowed wins;
        // l = 6 with s ≤ 3 → (2,2,2), total 12.
        let best = exhaustive_segmentations(6, 1, 3, usize::MAX, |lo, hi| {
            let d = (hi - lo) as f64;
            Some(d * d)
        })
        .unwrap();
        assert_eq!(best.0, vec![0, 2, 4, 6]);
        assert_eq!(best.1, 12.0);
        // with a layer cap of 2 only (2,2,2) survives at s=3
        let capped = exhaustive_segmentations(6, 3, 3, 2, |lo, hi| {
            let d = (hi - lo) as f64;
            Some(d * d)
        })
        .unwrap();
        assert_eq!(capped.0, vec![0, 2, 4, 6]);
        // cost rewarding long spans flips the winner to one segment
        let one = exhaustive_segmentations(6, 1, 3, usize::MAX, |lo, hi| {
            Some(100.0 / (hi - lo) as f64)
        })
        .unwrap();
        assert_eq!(one.0, vec![0, 6]);
    }

    #[test]
    fn segmentation_enumeration_memoizes_and_skips_invalid() {
        use std::collections::HashMap;
        let mut calls: HashMap<(usize, usize), usize> = HashMap::new();
        exhaustive_segmentations(7, 1, 4, usize::MAX, |lo, hi| {
            *calls.entry((lo, hi)).or_insert(0) += 1;
            Some((hi - lo) as f64)
        })
        .unwrap();
        assert!(!calls.is_empty());
        assert!(calls.values().all(|&n| n == 1), "{calls:?}");

        // spans over 2 layers unschedulable → only s ≥ ceil(5/2) = 3 works
        let r = exhaustive_segmentations(5, 1, 5, usize::MAX, |lo, hi| {
            if hi - lo <= 2 {
                Some(1.0)
            } else {
                None
            }
        })
        .unwrap();
        assert!(r.0.windows(2).all(|w| w[1] - w[0] <= 2));
        // nothing schedulable → None
        assert!(exhaustive_segmentations(4, 1, 2, usize::MAX, |_, _| None).is_none());
    }

    #[test]
    fn mode_segmentations_pick_cheaper_mode_per_segment() {
        // fused costs less on short spans, pipeline on long ones
        let cost = |lo: usize, hi: usize, mode: ExecMode| {
            let d = (hi - lo) as f64;
            Some(match mode {
                ExecMode::Fused => d * d,
                ExecMode::Pipeline => 4.0 * d,
            })
        };
        let (bounds, modes, total) =
            exhaustive_mode_segmentations(6, 2, 2, usize::MAX, cost).unwrap();
        // even split (3,3): fused 9 vs pipeline 12 per span → fused both
        assert_eq!(bounds, vec![0, 3, 6]);
        assert_eq!(modes, vec![ExecMode::Fused, ExecMode::Fused]);
        assert_eq!(total, 18.0);
        // one free segmentation: (1,5) with fused 1 + pipeline 20 = 21 …
        // the optimizer still prefers the even fused split
        let (_, modes1, total1) =
            exhaustive_mode_segmentations(6, 1, 6, usize::MAX, cost).unwrap();
        assert!(total1 <= total);
        assert!(!modes1.is_empty());
    }

    #[test]
    fn mode_segmentations_break_ties_toward_pipeline() {
        // span (0,2) ties across modes, span (2,4) is strictly cheaper
        // fused: the winner must be [Pipeline, Fused] — never Fused on
        // the tied span (the DP's "fused only when strictly cheaper").
        let cost = |lo: usize, _hi: usize, mode: ExecMode| {
            Some(match (lo, mode) {
                (0, _) => 5.0,
                (_, ExecMode::Pipeline) => 10.0,
                (_, ExecMode::Fused) => 3.0,
            })
        };
        let (bounds, modes, total) =
            exhaustive_mode_segmentations(4, 2, 2, 2, cost).unwrap();
        assert_eq!(bounds, vec![0, 2, 4]);
        assert_eq!(modes, vec![ExecMode::Pipeline, ExecMode::Fused]);
        assert_eq!(total, 8.0);
        // all-tied: all-pipeline wins outright
        let (_, modes2, _) =
            exhaustive_mode_segmentations(4, 2, 2, 2, |_, _, _| Some(1.0)).unwrap();
        assert_eq!(modes2, vec![ExecMode::Pipeline; 2]);
    }

    #[test]
    fn mode_segmentations_skip_unschedulable_pairs() {
        // pipeline unschedulable everywhere → fused-only assignments
        let (bounds, modes, _) = exhaustive_mode_segmentations(5, 1, 5, 2, |_, _, mode| {
            (mode == ExecMode::Fused).then_some(1.0)
        })
        .unwrap();
        assert!(modes.iter().all(|&m| m == ExecMode::Fused));
        assert!(bounds.windows(2).all(|w| w[1] - w[0] <= 2));
        // nothing schedulable at all → None
        assert!(exhaustive_mode_segmentations(4, 1, 2, usize::MAX, |_, _, _| None).is_none());
        // agrees with the mode-less enumeration when fused never helps
        let chain = |lo: usize, hi: usize| Some(((hi - lo) * (hi - lo)) as f64 + lo as f64);
        let plain = exhaustive_segmentations(7, 1, 4, usize::MAX, chain).unwrap();
        let moded = exhaustive_mode_segmentations(7, 1, 4, usize::MAX, |lo, hi, mode| {
            match mode {
                ExecMode::Pipeline => chain(lo, hi),
                ExecMode::Fused => None,
            }
        })
        .unwrap();
        assert_eq!(plain.0, moded.0);
        assert_eq!(plain.1.to_bits(), moded.2.to_bits());
    }

    #[test]
    fn cut_segmentation_matches_unrestricted_on_full_domain() {
        // With every position legal, the cut-set enumeration must agree
        // with the composition-based one bit for bit.
        let cuts: Vec<usize> = (1..7).collect();
        let cost = |lo: usize, hi: usize| {
            Some(((hi - lo) * (hi - lo)) as f64 + (lo % 3) as f64)
        };
        for (min_s, max_s, cap) in [(1usize, 4usize, usize::MAX), (2, 3, 3), (1, 7, 2)] {
            let a = exhaustive_segmentations(7, min_s, max_s, cap, cost);
            let b = exhaustive_cut_segmentations(7, &cuts, min_s, max_s, cap, cost);
            match (a, b) {
                (None, None) => {}
                (Some(a), Some(b)) => {
                    assert_eq!(a.1.to_bits(), b.1.to_bits(), "{min_s}..{max_s} cap {cap}");
                }
                (a, b) => panic!("unrestricted {a:?} vs cuts {b:?}"),
            }
        }
    }

    #[test]
    fn cut_segmentation_respects_restricted_domain() {
        // Quadratic cost wants a split at every layer; only 2 and 5 are
        // legal, so the best must use exactly those.
        let quad = |lo: usize, hi: usize| Some(((hi - lo) * (hi - lo)) as f64);
        let best = exhaustive_cut_segmentations(7, &[2, 5], 1, 7, usize::MAX, quad).unwrap();
        assert_eq!(best.0, vec![0, 2, 5, 7]);
        assert_eq!(best.1, 4.0 + 9.0 + 4.0);
        // a 3-layer cap keeps the same (only) fully-capped choice
        let capped = exhaustive_cut_segmentations(7, &[2, 5], 1, 7, 3, quad).unwrap();
        assert_eq!(capped.0, vec![0, 2, 5, 7]);
        // no cuts: multi-segment counts are infeasible
        assert!(
            exhaustive_cut_segmentations(7, &[], 2, 3, usize::MAX, |_, _| Some(1.0))
                .is_none()
        );
        let single =
            exhaustive_cut_segmentations(7, &[], 1, 3, usize::MAX, |_, _| Some(1.0))
                .unwrap();
        assert_eq!(single.0, vec![0, 7]);
    }

    #[test]
    fn exhaustive_scopenet_finds_optimum_and_search_is_near() {
        // ScopeNet (6 layers) on 8 chiplets: small enough for a full sweep.
        let net = scopenet();
        let mcm = McmConfig::paper_default(8);
        let opts = SimOptions { samples: 16, ..Default::default() };
        let ctx = EvalContext {
            net: &net,
            mcm: &mcm,
            opts: &opts,
            policy: StoragePolicy::Distributed,
            dram_fallback: true,
        };
        let ex = exhaustive_segment(&ctx, 0, net.len(), 16, ExhaustiveOptions::default());
        assert!(ex.valid > 100, "valid={}", ex.valid);
        assert!(ex.best_latency.is_finite());

        let found = search_segment(&ctx, 0, net.len(), 16, SearchOptions::default())
            .expect("search result");
        // The search must land in the top few percent of the population
        // (paper: top 0.05% on AlexNet/16; this tiny case is coarser).
        let rank = ex.rank_of(found.latency * 1.0001);
        assert!(rank <= 0.05, "rank = {rank}");
        // And can never beat the exhaustive optimum.
        assert!(found.latency >= ex.best_latency * 0.9999);
    }

    #[test]
    fn parallel_sweep_is_bit_identical_to_serial() {
        let net = scopenet();
        let mcm = McmConfig::paper_default(8);
        let serial_sim = SimOptions { samples: 4, threads: 1, ..Default::default() };
        let ctx1 = EvalContext {
            net: &net,
            mcm: &mcm,
            opts: &serial_sim,
            policy: StoragePolicy::Distributed,
            dram_fallback: true,
        };
        let serial = exhaustive_segment(&ctx1, 0, net.len(), 4, ExhaustiveOptions::default());
        for threads in [2usize, 8] {
            let par_sim = SimOptions { samples: 4, threads, ..Default::default() };
            let ctx_n = EvalContext {
                net: &net,
                mcm: &mcm,
                opts: &par_sim,
                policy: StoragePolicy::Distributed,
                dram_fallback: true,
            };
            let par =
                exhaustive_segment(&ctx_n, 0, net.len(), 4, ExhaustiveOptions::default());
            assert_eq!(serial.visited, par.visited, "threads={threads}");
            assert_eq!(serial.valid, par.valid, "threads={threads}");
            assert_eq!(
                serial.best_latency.to_bits(),
                par.best_latency.to_bits(),
                "threads={threads}"
            );
            assert_eq!(serial.best_schedule, par.best_schedule, "threads={threads}");
            assert_eq!(serial.latencies.len(), par.latencies.len());
            for (a, b) in serial.latencies.iter().zip(&par.latencies) {
                assert_eq!(a.to_bits(), b.to_bits(), "threads={threads}");
            }
        }
    }

    #[test]
    fn visit_cap_respected() {
        let net = scopenet();
        let mcm = McmConfig::paper_default(8);
        let opts = SimOptions { samples: 4, ..Default::default() };
        let ctx = EvalContext {
            net: &net,
            mcm: &mcm,
            opts: &opts,
            policy: StoragePolicy::Distributed,
            dram_fallback: true,
        };
        let ex = exhaustive_segment(
            &ctx,
            0,
            net.len(),
            4,
            ExhaustiveOptions { max_visits: 500, ..Default::default() },
        );
        assert!(ex.visited <= 501);
    }

    #[test]
    fn histogram_and_rank() {
        let res = ExhaustiveResult {
            valid: 4,
            visited: 4,
            best_latency: 1.0,
            best_schedule: None,
            latencies: vec![1.0, 2.0, 3.0, 4.0],
        };
        assert_eq!(res.rank_of(1.0), 0.0);
        assert_eq!(res.rank_of(2.5), 0.5);
        let h = res.histogram(4);
        assert_eq!(h.total, 4);
    }

    #[test]
    fn empty_population_has_nan_rank_and_empty_histogram() {
        // An all-invalid sweep used to report rank 0.0 ("best possible")
        // and panic inside Histogram::new on ±∞ bounds.
        let res = ExhaustiveResult {
            valid: 0,
            visited: 10,
            best_latency: f64::INFINITY,
            best_schedule: None,
            latencies: vec![],
        };
        assert!(res.rank_of(123.0).is_nan());
        assert!(!(res.rank_of(123.0) <= 0.05), "NaN must fail rank bounds");
        let h = res.histogram(8);
        assert_eq!(h.total, 0);
        assert_eq!(h.counts.len(), 8);
        assert!(h.proportions().iter().all(|&p| p == 0.0));
        assert_eq!(h.frac_below(0.5), 0.0);
    }

    #[test]
    fn share_splits_enumerate_lexicographically_within_budget() {
        let mut seen: Vec<Vec<usize>> = Vec::new();
        let done = for_each_share_split(2, &[1, 2, 3], 4, &mut |split| {
            seen.push(split.to_vec());
            true
        });
        assert!(done);
        assert_eq!(
            seen,
            vec![
                vec![1, 1],
                vec![1, 2],
                vec![1, 3],
                vec![2, 1],
                vec![2, 2],
                vec![3, 1],
            ]
        );
        // early stop propagates
        let mut count = 0usize;
        let done = for_each_share_split(2, &[1, 2, 3], 4, &mut |_| {
            count += 1;
            count < 3
        });
        assert!(!done);
        assert_eq!(count, 3);
        // degenerate cases: zero models is vacuously complete; a budget
        // below the smallest share visits nothing
        assert!(for_each_share_split(0, &[1, 2], 4, &mut |_| panic!("no splits")));
        assert!(for_each_share_split(2, &[3, 4], 5, &mut |_| panic!("cannot fit")));
    }
}
