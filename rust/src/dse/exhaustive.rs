//! Exhaustive DSE over one segment — the Fig. 8 validation harness.
//!
//! Enumerates every (cluster composition, region composition, partition)
//! triple for a segment on `C` chiplets, evaluates each with the same
//! `Forward()` as the search algorithm, and reports the processing-time
//! distribution plus the exact rank of a given latency.
//!
//! Partition space: by default the `L+1` WSP→ISP transitions (the space
//! Scope actually searches); `PartitionSpace::Full` sweeps all `2^L`
//! masks — feasible for AlexNet-scale `L` (the paper also restricts the
//! exhaustive comparison to "the smallest-scale setting").

use crate::pipeline::schedule::{Partition, SegmentSchedule};
use crate::pipeline::timeline::{eval_segment, EvalContext};
use crate::scope::partition::{mask_partitions, transition_partitions};
use crate::util::stats::Histogram;

/// Which per-layer partition assignments to enumerate.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PartitionSpace {
    /// The L+1 single-transition assignments.
    Transitions,
    /// All 2^L masks (L ≤ 24 guard).
    Full,
}

/// Aggregated outcome of an exhaustive sweep.
#[derive(Clone, Debug)]
pub struct ExhaustiveResult {
    /// Valid (capacity-respecting) configurations evaluated.
    pub valid: u64,
    /// Total configurations visited.
    pub visited: u64,
    /// Best latency found (cycles for the batch).
    pub best_latency: f64,
    pub best_schedule: Option<SegmentSchedule>,
    /// All valid latencies, for ranking (capped collection — see
    /// `ExhaustiveOptions::keep_latencies`).
    pub latencies: Vec<f64>,
}

/// Sweep controls.
#[derive(Clone, Copy, Debug)]
pub struct ExhaustiveOptions {
    pub partition_space: PartitionSpace,
    /// Stop after this many visited configs (0 = no cap).
    pub max_visits: u64,
    /// Keep at most this many latencies (reservoir-free prefix keep; the
    /// rank statistics use all of them when under the cap).
    pub keep_latencies: usize,
}

impl Default for ExhaustiveOptions {
    fn default() -> Self {
        ExhaustiveOptions {
            partition_space: PartitionSpace::Transitions,
            max_visits: 0,
            keep_latencies: 50_000_000,
        }
    }
}

/// Visit all compositions of `total` into `parts` positive ordered parts,
/// calling `f(&parts_vec)`; returns false if the visitor aborted.
fn for_each_composition<F: FnMut(&[usize]) -> bool>(total: usize, parts: usize, f: &mut F) -> bool {
    // iterative enumeration of split points via a stack of part sizes
    fn rec<F: FnMut(&[usize]) -> bool>(
        remaining: usize,
        parts_left: usize,
        acc: &mut Vec<usize>,
        f: &mut F,
    ) -> bool {
        if parts_left == 1 {
            acc.push(remaining);
            let go = f(acc);
            acc.pop();
            return go;
        }
        // leave at least 1 per remaining part
        for take in 1..=(remaining - (parts_left - 1)) {
            acc.push(take);
            let go = rec(remaining - take, parts_left - 1, acc, f);
            acc.pop();
            if !go {
                return false;
            }
        }
        true
    }
    if parts == 0 || parts > total {
        return true;
    }
    let mut acc = Vec::with_capacity(parts);
    rec(total, parts, &mut acc, f)
}

/// Run the exhaustive sweep over segment `[lo, hi)`.
pub fn exhaustive_segment(
    ctx: &EvalContext,
    lo: usize,
    hi: usize,
    m: u64,
    opts: ExhaustiveOptions,
) -> ExhaustiveResult {
    let l = hi - lo;
    let c = ctx.mcm.chiplets;
    assert!(l <= 24, "exhaustive sweep guard: L = {l} too deep");
    let partitions: Vec<Vec<Partition>> = match opts.partition_space {
        PartitionSpace::Transitions => {
            (0..=l).map(|idx| transition_partitions(l, idx)).collect()
        }
        PartitionSpace::Full => (0u64..(1 << l))
            .map(|mask| mask_partitions(l, mask))
            .collect(),
    };
    let mut res = ExhaustiveResult {
        valid: 0,
        visited: 0,
        best_latency: f64::INFINITY,
        best_schedule: None,
        latencies: Vec::new(),
    };
    // cluster compositions: layer counts per cluster, for every n
    for n in 1..=l.min(c) {
        let completed = for_each_composition(l, n, &mut |layer_parts| {
            // bounds from layer counts
            let mut bounds = Vec::with_capacity(n + 1);
            bounds.push(lo);
            for &p in layer_parts {
                bounds.push(bounds.last().unwrap() + p);
            }
            // region compositions; false propagates a visit-cap abort
            for_each_composition(c, n, &mut |regions| {
                for parts in &partitions {
                    res.visited += 1;
                    if opts.max_visits > 0 && res.visited > opts.max_visits {
                        return false;
                    }
                    let seg = SegmentSchedule {
                        lo,
                        hi,
                        bounds: bounds.clone(),
                        regions: regions.to_vec(),
                        partitions: parts.clone(),
                    };
                    let ev = eval_segment(ctx, &seg, m);
                    if ev.error.is_some() {
                        continue;
                    }
                    let lat = ev.preload_cycles + ev.pipeline_cycles;
                    res.valid += 1;
                    if res.latencies.len() < opts.keep_latencies {
                        res.latencies.push(lat);
                    }
                    if lat < res.best_latency {
                        res.best_latency = lat;
                        res.best_schedule = Some(seg);
                    }
                }
                true
            })
        });
        if !completed {
            break;
        }
    }
    res
}

impl ExhaustiveResult {
    /// Fraction of valid schedules strictly better than `latency`
    /// (the paper's "top 0.05%" is `rank_of(scope_latency) ≤ 0.0005`).
    pub fn rank_of(&self, latency: f64) -> f64 {
        if self.latencies.is_empty() {
            return 0.0;
        }
        let better = self.latencies.iter().filter(|&&x| x < latency).count();
        better as f64 / self.latencies.len() as f64
    }

    /// Processing-time histogram over the valid population (Fig. 8's
    /// x-axis buckets).
    pub fn histogram(&self, bins: usize) -> Histogram {
        let lo = self.latencies.iter().copied().fold(f64::INFINITY, f64::min);
        let hi = self.latencies.iter().copied().fold(0.0f64, f64::max);
        let mut h = Histogram::new(lo, (hi * 1.0001).max(lo + 1.0), bins);
        for &x in &self.latencies {
            h.add(x);
        }
        h
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::McmConfig;
    use crate::config::SimOptions;
    use crate::model::zoo::scopenet;
    use crate::scope::{search_segment, SearchOptions};
    use crate::storage::StoragePolicy;

    fn count_compositions(total: usize, parts: usize) -> u64 {
        let mut n = 0u64;
        for_each_composition(total, parts, &mut |_| {
            n += 1;
            true
        });
        n
    }

    #[test]
    fn composition_counts_are_binomial() {
        // compositions of T into P parts = C(T−1, P−1)
        assert_eq!(count_compositions(5, 1), 1);
        assert_eq!(count_compositions(5, 2), 4);
        assert_eq!(count_compositions(5, 3), 6);
        assert_eq!(count_compositions(6, 6), 1);
        assert_eq!(count_compositions(3, 4), 0);
    }

    #[test]
    fn composition_visitor_aborts() {
        let mut seen = 0;
        for_each_composition(6, 2, &mut |_| {
            seen += 1;
            seen < 3
        });
        assert_eq!(seen, 3);
    }

    #[test]
    fn exhaustive_scopenet_finds_optimum_and_search_is_near() {
        // ScopeNet (6 layers) on 8 chiplets: small enough for a full sweep.
        let net = scopenet();
        let mcm = McmConfig::paper_default(8);
        let opts = SimOptions { samples: 16, ..Default::default() };
        let ctx = EvalContext {
            net: &net,
            mcm: &mcm,
            opts: &opts,
            policy: StoragePolicy::Distributed,
            dram_fallback: true,
        };
        let ex = exhaustive_segment(&ctx, 0, net.len(), 16, ExhaustiveOptions::default());
        assert!(ex.valid > 100, "valid={}", ex.valid);
        assert!(ex.best_latency.is_finite());

        let found = search_segment(&ctx, 0, net.len(), 16, SearchOptions::default())
            .expect("search result");
        // The search must land in the top few percent of the population
        // (paper: top 0.05% on AlexNet/16; this tiny case is coarser).
        let rank = ex.rank_of(found.latency * 1.0001);
        assert!(rank <= 0.05, "rank = {rank}");
        // And can never beat the exhaustive optimum.
        assert!(found.latency >= ex.best_latency * 0.9999);
    }

    #[test]
    fn visit_cap_respected() {
        let net = scopenet();
        let mcm = McmConfig::paper_default(8);
        let opts = SimOptions { samples: 4, ..Default::default() };
        let ctx = EvalContext {
            net: &net,
            mcm: &mcm,
            opts: &opts,
            policy: StoragePolicy::Distributed,
            dram_fallback: true,
        };
        let ex = exhaustive_segment(
            &ctx,
            0,
            net.len(),
            4,
            ExhaustiveOptions { max_visits: 500, ..Default::default() },
        );
        assert!(ex.visited <= 501);
    }

    #[test]
    fn histogram_and_rank() {
        let res = ExhaustiveResult {
            valid: 4,
            visited: 4,
            best_latency: 1.0,
            best_schedule: None,
            latencies: vec![1.0, 2.0, 3.0, 4.0],
        };
        assert_eq!(res.rank_of(1.0), 0.0);
        assert_eq!(res.rank_of(2.5), 0.5);
        let h = res.histogram(4);
        assert_eq!(h.total, 4);
    }
}
