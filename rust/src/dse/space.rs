//! Search-space counting — the paper's Equ. 8–9, computed exactly.
//!
//! `Q(N; L, C) = C(L−1, N−1) · C(C−1, N−1)` cluster/region configurations,
//! `Q_total = 2^L · Σ_{i=1..L} Q(i; L, C)` including per-layer partitions.
//! ResNet-152 on 256 chiplets gives ≈ 8.27 × 10^164 (the paper's headline
//! intractability figure); we verify the exponent with exact bignums.

use crate::util::bignum::BigUint;

/// Equ. 8: configurations with exactly `n` clusters.
pub fn q_configs(n: u64, l: u64, c: u64) -> BigUint {
    if n == 0 || n > l || n > c {
        return BigUint::zero();
    }
    BigUint::binomial(l - 1, n - 1).mul(&BigUint::binomial(c - 1, n - 1))
}

/// Σ_{i=1..L} Q(i; L, C) — cluster/region configurations for one segment.
/// By Vandermonde this equals C(L+C−2, L−1).
pub fn q_cluster_region(l: u64, c: u64) -> BigUint {
    let mut sum = BigUint::zero();
    for i in 1..=l {
        sum = sum.add(&q_configs(i, l, c));
    }
    sum
}

/// Equ. 9: the complete per-segment space including 2^L partitions.
pub fn q_total(l: u64, c: u64) -> BigUint {
    BigUint::pow2(l as u32).mul(&q_cluster_region(l, c))
}

/// The size of Scope's *reduced* space: (L+1 transitions) × (L CMT rows)
/// × (≤ max_iters region moves) — linear-ish, for the complexity-reduction
/// report row.
pub fn scope_reduced_space(l: u64, region_iters: u64) -> BigUint {
    BigUint::from_u64(l + 1)
        .mul(&BigUint::from_u64(l))
        .mul(&BigUint::from_u64(region_iters.max(1)))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn q_small_by_hand() {
        // L=3, C=3, n=2: C(2,1)·C(2,1) = 4
        assert_eq!(q_configs(2, 3, 3).to_decimal(), "4");
        assert_eq!(q_configs(0, 3, 3).to_decimal(), "0");
        assert_eq!(q_configs(4, 3, 9).to_decimal(), "0");
        // n bounded by chiplets too
        assert_eq!(q_configs(3, 5, 2).to_decimal(), "0");
    }

    #[test]
    fn vandermonde_closed_form() {
        // Σ Q(i; L, C) = C(L+C−2, L−1)
        for (l, c) in [(8u64, 16u64), (5, 5), (16, 16)] {
            assert_eq!(q_cluster_region(l, c), BigUint::binomial(l + c - 2, l - 1));
        }
    }

    #[test]
    fn alexnet_16_space() {
        // L=8, C=16: Σ Q = C(22,7) = 170544; ×2^8 = 43,659,264.
        assert_eq!(q_cluster_region(8, 16).to_decimal(), "170544");
        assert_eq!(q_total(8, 16).to_decimal(), "43659264");
    }

    #[test]
    fn resnet152_256_is_paper_scale() {
        // The paper: Q_total ≈ 8.27 × 10^164 for ResNet-152 (per-segment
        // L = 156 chain, C = 256).
        let q = q_total(156, 256);
        let log10 = q.log10();
        assert!(
            (163.0..166.5).contains(&log10),
            "log10(Q_total) = {log10}, paper says ≈164.9"
        );
    }

    #[test]
    fn reduction_is_astronomic() {
        let full = q_total(156, 256).log10();
        let reduced = scope_reduced_space(156, 64).log10();
        assert!(full - reduced > 150.0, "reduction {full} → {reduced}");
    }
}
