//! DSE validation: exact search-space counting (Equ. 8–9) and the
//! exhaustive sweep used by the Fig. 8 comparison.

pub mod exhaustive;
pub mod space;

pub use exhaustive::{
    exhaustive_segment, ExhaustiveOptions, ExhaustiveResult, PartitionSpace,
};
pub use space::{q_cluster_region, q_configs, q_total, scope_reduced_space};
