//! DSE validation: exact search-space counting (Equ. 8–9), the exhaustive
//! sweep used by the Fig. 8 comparison, and the deterministic parallel
//! executor both sweeps (and Algorithm 1) fan candidates across.

pub mod exhaustive;
pub mod parallel;
pub mod space;

pub use exhaustive::{
    exhaustive_cut_segmentations, exhaustive_segment, exhaustive_segmentations,
    ExhaustiveOptions, ExhaustiveResult, PartitionSpace,
};
pub use parallel::{par_map, resolve_threads};
pub use space::{q_cluster_region, q_configs, q_total, scope_reduced_space};
