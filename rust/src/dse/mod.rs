//! DSE validation: exact search-space counting (Equ. 8–9), the exhaustive
//! sweeps — the Fig. 8 schedule enumeration, the boundary/cut-set
//! segmentation ground truths, and the multi-model chiplet-split
//! enumeration — and the deterministic parallel executor every sweep (and
//! Algorithm 1) fans candidates across.

pub mod exhaustive;
pub mod parallel;
pub mod space;

pub use exhaustive::{
    exhaustive_cut_segmentations, exhaustive_segment, exhaustive_segmentations,
    for_each_share_split, ExhaustiveOptions, ExhaustiveResult, PartitionSpace,
};
pub use parallel::{par_map, resolve_threads};
pub use space::{q_cluster_region, q_configs, q_total, scope_reduced_space};
