//! Distributed weight buffering (paper §III-B) and capacity validation.
//!
//! A cluster's chiplets must hold its layers' weights on-package across the
//! whole segment (DRAM re-fetch per sample would dominate). Footprint per
//! chiplet depends on partition and storage policy:
//!
//! * ISP layer: weights are channel-sharded anyway → `ceil(W/R)` resident.
//! * WSP layer, **replicated** policy (baselines): full `W` resident on
//!   every chiplet, no preparation cost.
//! * WSP layer, **distributed** policy (Scope §III-B): `ceil(W/R)` tile
//!   resident; the full replica is materialized only during that layer's
//!   turn via a NoP all-gather in the preparation phase, then dropped. The
//!   steady-state footprint is `Σ tiles + max_l (W_l − tile_l)` (one
//!   transient replica alive at a time).

use crate::model::Layer;
use crate::pipeline::schedule::Partition;
use crate::util::ceil_div;

/// Weight storage policy for WSP layers.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum StoragePolicy {
    /// Full replica resident (no prep exchange) — baseline behaviour.
    Replicated,
    /// §III-B tiled residency + preparation-phase all-gather — Scope.
    Distributed,
}

/// Resident weight tile of one layer per chiplet (bytes).
pub fn resident_tile(layer: &Layer, p: Partition, r: u64, policy: StoragePolicy) -> u64 {
    let w = layer.weight_bytes();
    match (p, policy) {
        (Partition::Isp, _) => ceil_div(w, r),
        (Partition::Wsp, StoragePolicy::Distributed) => ceil_div(w, r),
        (Partition::Wsp, StoragePolicy::Replicated) => w,
    }
}

/// Transient extra bytes needed while `layer` is the one computing: under
/// the distributed policy a WSP layer inflates its tile to the full matrix.
pub fn transient_extra(layer: &Layer, p: Partition, r: u64, policy: StoragePolicy) -> u64 {
    match (p, policy) {
        (Partition::Wsp, StoragePolicy::Distributed) => {
            layer.weight_bytes() - ceil_div(layer.weight_bytes(), r)
        }
        _ => 0,
    }
}

/// Bytes each chiplet must *receive* over the NoP during the preparation
/// phase of `layer` (Equ. 4's NoP side): the (R−1)/R missing share of a
/// distributed WSP matrix. Zero for ISP or replicated WSP.
pub fn prep_exchange_bytes(layer: &Layer, p: Partition, r: u64, policy: StoragePolicy) -> u64 {
    transient_extra(layer, p, r, policy)
}

/// Peak per-chiplet weight footprint of a cluster (bytes): all resident
/// tiles plus the largest single transient replica.
pub fn cluster_footprint(
    layers: &[Layer],
    partitions: &[Partition],
    r: u64,
    policy: StoragePolicy,
) -> u64 {
    debug_assert_eq!(layers.len(), partitions.len());
    let resident: u64 = layers
        .iter()
        .zip(partitions)
        .map(|(l, &p)| resident_tile(l, p, r, policy))
        .sum();
    let transient = layers
        .iter()
        .zip(partitions)
        .map(|(l, &p)| transient_extra(l, p, r, policy))
        .max()
        .unwrap_or(0);
    resident + transient
}

/// How one layer's weights live on the region's chiplets.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum LayerResidency {
    /// The full working copy is resident (ISP shard, or a WSP replica):
    /// zero preparation cost.
    Resident,
    /// §III-B distributed tiles: `W/R` resident, the replica is assembled
    /// by a NoP all-gather in the preparation phase (WSP + Distributed
    /// policy only).
    TiledExchange,
    /// No on-chip copy: weights stream from DRAM every sample (Equ. 4's
    /// off-chip path — "DRAM access significantly degrades performance").
    Streamed,
}

/// Per-cluster storage plan chosen under the chiplet capacity.
#[derive(Clone, Debug, PartialEq)]
pub struct ResidencyPlan {
    pub residency: Vec<LayerResidency>,
    /// Peak per-chiplet footprint of the plan (bytes).
    pub footprint: u64,
}

impl ResidencyPlan {
    pub fn streamed_count(&self) -> usize {
        self.residency
            .iter()
            .filter(|&&r| r == LayerResidency::Streamed)
            .count()
    }

    pub fn fully_on_chip(&self) -> bool {
        self.streamed_count() == 0
    }
}

/// Build the residency plan for a cluster under `capacity` bytes/chiplet.
///
/// Greedy demotion: start every layer at its cheapest-prep state (full
/// working copy resident), then while the footprint overflows, demote the
/// most demanding layer one step — WSP replicas first demote to §III-B
/// tiles (Distributed policy only), then to DRAM streaming. ISP shards go
/// straight to streaming (they are already minimal on-chip).
pub fn plan_cluster(
    layers: &[Layer],
    partitions: &[Partition],
    r: u64,
    policy: StoragePolicy,
    capacity: u64,
) -> ResidencyPlan {
    debug_assert_eq!(layers.len(), partitions.len());
    let n = layers.len();
    // On-chip demand of a layer in a given state: (steady bytes, transient
    // extra while it computes).
    let demand = |i: usize, st: LayerResidency| -> (u64, u64) {
        let w = layers[i].weight_bytes();
        match (partitions[i], st) {
            (_, LayerResidency::Streamed) => (0, 0),
            (Partition::Isp, _) => (ceil_div(w, r), 0),
            (Partition::Wsp, LayerResidency::Resident) => (w, 0),
            (Partition::Wsp, LayerResidency::TiledExchange) => {
                (ceil_div(w, r), w - ceil_div(w, r))
            }
        }
    };
    let next_state = |i: usize, cur: LayerResidency| -> Option<LayerResidency> {
        match (partitions[i], policy, cur) {
            (_, _, LayerResidency::Streamed) => None,
            (Partition::Wsp, StoragePolicy::Distributed, LayerResidency::Resident) => {
                Some(LayerResidency::TiledExchange)
            }
            (_, _, _) => Some(LayerResidency::Streamed),
        }
    };
    // Incremental state: per-layer (steady, transient) demands, the steady
    // sum, and the top-2 transients (so replacing the max is O(1)). This
    // loop sits inside the DSE's Forward() — no allocation per candidate.
    let mut plan = vec![LayerResidency::Resident; n];
    let mut steady: Vec<u64> = (0..n).map(|i| demand(i, plan[i]).0).collect();
    let mut trans: Vec<u64> = (0..n).map(|i| demand(i, plan[i]).1).collect();
    let mut steady_sum: u64 = steady.iter().sum();
    let top2 = |trans: &[u64]| -> (u64, u64) {
        let (mut m1, mut m2) = (0u64, 0u64);
        for &t in trans {
            if t > m1 {
                m2 = m1;
                m1 = t;
            } else if t > m2 {
                m2 = t;
            }
        }
        (m1, m2)
    };
    let (mut max1, mut max2) = top2(&trans);
    loop {
        let foot = steady_sum + max1;
        if foot <= capacity {
            return ResidencyPlan { residency: plan, footprint: foot };
        }
        // candidate demotions: O(1) footprint delta each
        let mut best: Option<(u64, usize, LayerResidency)> = None;
        for i in 0..n {
            let Some(st) = next_state(i, plan[i]) else { continue };
            let (ns, nt) = demand(i, st);
            let new_steady = steady_sum - steady[i] + ns;
            let new_max = if trans[i] == max1 {
                max2.max(nt)
            } else {
                max1.max(nt)
            };
            let saving = foot.saturating_sub(new_steady + new_max);
            if best.map(|b| saving > b.0).unwrap_or(true) {
                best = Some((saving, i, st));
            }
        }
        let (saving, i, st) = match best {
            Some(b) => b,
            None => return ResidencyPlan { residency: plan, footprint: 0 },
        };
        if saving == 0 {
            // transient dominated by another layer: demote the largest
            // remaining anyway so the loop always terminates
            let j = (0..n)
                .filter(|&j| plan[j] != LayerResidency::Streamed)
                .max_by_key(|&j| steady[j] + trans[j]);
            let Some(j) = j else {
                return ResidencyPlan { residency: plan, footprint: 0 };
            };
            let st = next_state(j, plan[j]).unwrap();
            let (ns, nt) = demand(j, st);
            plan[j] = st;
            steady_sum = steady_sum - steady[j] + ns;
            steady[j] = ns;
            trans[j] = nt;
            (max1, max2) = top2(&trans);
            continue;
        }
        let (ns, nt) = demand(i, st);
        plan[i] = st;
        steady_sum = steady_sum - steady[i] + ns;
        steady[i] = ns;
        trans[i] = nt;
        (max1, max2) = top2(&trans);
    }
}

/// Check a cluster against the chiplet weight-buffer capacity.
pub fn cluster_fits(
    layers: &[Layer],
    partitions: &[Partition],
    r: u64,
    policy: StoragePolicy,
    capacity: u64,
) -> bool {
    cluster_footprint(layers, partitions, r, policy) <= capacity
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::Layer;

    fn l(w_kb: u64) -> Layer {
        // 1×1 conv with cin=1024, cout = w_kb: weight bytes = 1024·cout
        Layer::conv("l", 8, 8, 1024, w_kb, 1, 1, 0)
    }

    #[test]
    fn isp_always_sharded() {
        let layer = l(512); // 512 KiB weights
        for policy in [StoragePolicy::Replicated, StoragePolicy::Distributed] {
            assert_eq!(
                resident_tile(&layer, Partition::Isp, 4, policy),
                layer.weight_bytes() / 4
            );
            assert_eq!(prep_exchange_bytes(&layer, Partition::Isp, 4, policy), 0);
        }
    }

    #[test]
    fn wsp_replicated_vs_distributed() {
        let layer = l(512);
        let w = layer.weight_bytes();
        assert_eq!(
            resident_tile(&layer, Partition::Wsp, 4, StoragePolicy::Replicated),
            w
        );
        assert_eq!(
            resident_tile(&layer, Partition::Wsp, 4, StoragePolicy::Distributed),
            w / 4
        );
        assert_eq!(
            prep_exchange_bytes(&layer, Partition::Wsp, 4, StoragePolicy::Distributed),
            w - w / 4
        );
    }

    #[test]
    fn distributed_shrinks_multi_wsp_cluster_footprint() {
        // Three 512 KiB WSP layers over 4 chiplets, 1 MiB capacity:
        // replicated: 3 × 512 KiB = 1.5 MiB → overflow.
        // distributed: 3 × 128 KiB + 384 KiB transient = 768 KiB → fits.
        let layers = vec![l(512), l(512), l(512)];
        let parts = vec![Partition::Wsp; 3];
        let cap = 1 << 20;
        assert!(!cluster_fits(&layers, &parts, 4, StoragePolicy::Replicated, cap));
        assert!(cluster_fits(&layers, &parts, 4, StoragePolicy::Distributed, cap));
    }

    #[test]
    fn footprint_monotone_in_chiplets() {
        let layers = vec![l(512), l(256)];
        let parts = vec![Partition::Wsp; 2];
        let f2 = cluster_footprint(&layers, &parts, 2, StoragePolicy::Distributed);
        let f8 = cluster_footprint(&layers, &parts, 8, StoragePolicy::Distributed);
        assert!(f8 < f2);
    }

    #[test]
    fn single_chiplet_has_no_exchange() {
        let layer = l(512);
        assert_eq!(
            prep_exchange_bytes(&layer, Partition::Wsp, 1, StoragePolicy::Distributed),
            0
        );
        assert_eq!(
            cluster_footprint(&[layer.clone()], &[Partition::Wsp], 1, StoragePolicy::Distributed),
            layer.weight_bytes()
        );
    }
}
