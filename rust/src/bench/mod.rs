//! Minimal benchmark harness (criterion is not in the offline vendor set).
//!
//! Benches are plain `harness = false` binaries that time closures with
//! warm-up + repeated measurement and print mean/stddev rows, then emit
//! the paper-figure tables through `report::figures`.

use std::time::Instant;

use crate::util::stats;
use crate::util::table::Table;

/// One timed measurement series.
#[derive(Clone, Debug)]
pub struct Measurement {
    pub name: String,
    /// Seconds per iteration.
    pub samples: Vec<f64>,
}

impl Measurement {
    pub fn mean(&self) -> f64 {
        stats::mean(&self.samples)
    }

    pub fn stddev(&self) -> f64 {
        stats::stddev(&self.samples)
    }
}

/// Time `f` with `warmup` discarded runs and `iters` measured runs.
pub fn bench<F: FnMut()>(name: &str, warmup: usize, iters: usize, mut f: F) -> Measurement {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters.max(1) {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_secs_f64());
    }
    Measurement { name: name.to_string(), samples }
}

/// Render a set of measurements as a table.
pub fn report(title: &str, measurements: &[Measurement]) -> Table {
    let mut t = Table::new(title, &["bench", "mean", "stddev", "iters"]);
    for m in measurements {
        t.row(vec![
            m.name.clone(),
            humanize_secs(m.mean()),
            humanize_secs(m.stddev()),
            m.samples.len().to_string(),
        ]);
    }
    t
}

/// The `SCOPE_SEGMENTER` env knob shared by the benches: pick the segment
/// allocator (`balanced` default, `dp`) without recompiling. Panics on an
/// unknown value, listing the options — benches should fail loudly, not
/// silently fall back.
pub fn segmenter_from_env() -> crate::scope::SegmenterKind {
    match std::env::var("SCOPE_SEGMENTER") {
        Err(_) => crate::scope::SegmenterKind::Balanced,
        Ok(v) => crate::scope::SegmenterKind::parse(&v)
            .unwrap_or_else(|e| panic!("SCOPE_SEGMENTER: {e}")),
    }
}

/// The `SCOPE_CACHE_STORE` env knob shared by the benches: enable the
/// process-wide span/cluster cache store (`1`/`true`; default off, like
/// `SimOptions::cache_store`). Results are bit-identical either way — the
/// store only changes how much work repeated sweeps re-pay.
pub fn cache_store_from_env() -> bool {
    match std::env::var("SCOPE_CACHE_STORE") {
        Err(_) => false,
        Ok(v) => match v.as_str() {
            "1" | "true" => true,
            "0" | "false" => false,
            other => panic!("SCOPE_CACHE_STORE expects 0/1/true/false, got {other:?}"),
        },
    }
}

/// Human-friendly seconds.
pub fn humanize_secs(s: f64) -> String {
    if s >= 1.0 {
        format!("{s:.3} s")
    } else if s >= 1e-3 {
        format!("{:.3} ms", s * 1e3)
    } else if s >= 1e-6 {
        format!("{:.3} µs", s * 1e6)
    } else {
        format!("{:.1} ns", s * 1e9)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures() {
        let m = bench("noop", 1, 5, || {
            std::hint::black_box(1 + 1);
        });
        assert_eq!(m.samples.len(), 5);
        assert!(m.mean() >= 0.0);
    }

    #[test]
    fn humanize() {
        assert_eq!(humanize_secs(2.5), "2.500 s");
        assert_eq!(humanize_secs(0.0025), "2.500 ms");
        assert_eq!(humanize_secs(2.5e-6), "2.500 µs");
        assert_eq!(humanize_secs(3e-9), "3.0 ns");
    }

    #[test]
    fn report_renders() {
        let m = bench("x", 0, 2, || {});
        let t = report("t", &[m]);
        assert!(t.render().contains("x"));
    }
}
