//! Time-series acceptance tests: `--timeseries-out` writes byte-identical
//! `scope-timeseries-v1` JSON + CSV twins at every `--threads` setting and
//! across repeat runs while leaving the rest of stdout untouched, a seeded
//! flash crowd produces a windowed SLO drift event even though the
//! whole-run p99 stays inside the declared bound, and every malformed
//! time-series flag is rejected naming the offender.

use std::path::PathBuf;
use std::process::Command;

use scope::util::json::Json;

fn run_cli(args: &[&str]) -> String {
    let out = Command::new(env!("CARGO_BIN_EXE_scope"))
        .args(args)
        .output()
        .expect("scope binary runs");
    assert!(
        out.status.success(),
        "scope {:?} failed: {}",
        args,
        String::from_utf8_lossy(&out.stderr)
    );
    String::from_utf8(out.stdout).expect("utf8 stdout")
}

/// Run the CLI expecting a failure; returns stderr for error-text checks.
fn run_cli_err(args: &[&str]) -> String {
    let out = Command::new(env!("CARGO_BIN_EXE_scope"))
        .args(args)
        .output()
        .expect("scope binary runs");
    assert!(!out.status.success(), "scope {args:?} unexpectedly succeeded");
    String::from_utf8_lossy(&out.stderr).into_owned()
}

/// Unique temp path per (process, label) so parallel tests never collide.
fn tmp(label: &str) -> PathBuf {
    std::env::temp_dir().join(format!("scope_ts_{}_{label}", std::process::id()))
}

/// Stdout with the `timeseries: wrote ...` lines removed (their paths
/// differ per invocation); everything else must be unaffected.
fn strip_ts_lines(out: &str) -> String {
    out.lines()
        .filter(|l| !l.starts_with("timeseries: wrote"))
        .map(|l| format!("{l}\n"))
        .collect()
}

/// The schedule-driven serving run the determinism suite replays: a flash
/// crowd on the standard mix with a declared (generous) SLO, so the drift
/// summary line and the windowed series are both on the printed surface.
const SERVE_ARGS: &[&str] = &[
    "serve",
    "--models",
    "serving_mix",
    "--seed",
    "7",
    "--chiplets",
    "16",
    "--quantum",
    "8",
    "--samples",
    "4",
    "--batch",
    "2",
    "--arrival-rate",
    "40",
    "--horizon",
    "0.05",
    "--rate-schedule",
    "flash",
    "--slo",
    "5000",
    "--window",
    "2ms",
];

#[test]
fn timeseries_artifacts_are_bit_identical_and_leave_results_unchanged() {
    let base = run_cli(SERVE_ARGS);
    assert!(base.contains("serving simulation"), "{base}");
    assert!(base.contains("scheduled poisson"), "{base}");
    assert!(base.contains("slo drift:"), "{base}");

    let mut jsons: Vec<String> = Vec::new();
    let mut csvs: Vec<String> = Vec::new();
    // threads 1/2/8 plus a plain repeat of threads 1: both exported twins
    // must match byte for byte, and the report must not notice the export
    for (i, threads) in ["1", "2", "8", "1"].iter().enumerate() {
        let j_path = tmp(&format!("serve_{i}.json"));
        let j_s = j_path.display().to_string();
        let c_s = j_s.strip_suffix(".json").unwrap().to_string() + ".csv";
        let mut args = SERVE_ARGS.to_vec();
        args.extend(["--threads", threads, "--timeseries-out", &j_s]);
        let out = run_cli(&args);
        assert!(out.contains("timeseries: wrote"), "{out}");
        assert_eq!(
            strip_ts_lines(&out),
            base,
            "--threads {threads} with --timeseries-out drifted from the plain run"
        );
        jsons.push(std::fs::read_to_string(&j_path).expect("json twin"));
        csvs.push(std::fs::read_to_string(&c_s).expect("csv twin"));
        let _ = std::fs::remove_file(&j_path);
        let _ = std::fs::remove_file(&c_s);
    }
    for i in 1..jsons.len() {
        assert_eq!(jsons[0], jsons[i], "json artifact {i} differs from the first");
        assert_eq!(csvs[0], csvs[i], "csv artifact {i} differs from the first");
    }

    // versioned schema: window metadata, per-model series, drift trigger
    let doc = Json::parse(&jsons[0]).expect("timeseries parses as JSON");
    assert_eq!(doc.get("schema").unwrap().as_str().unwrap(), "scope-timeseries-v1");
    let windows = doc.get("windows").unwrap().as_f64().unwrap() as usize;
    let series = doc.get("series").unwrap().as_arr().expect("series array");
    assert!(windows > 0, "no windows in the export");
    assert_eq!(series.len(), windows);
    assert_eq!(doc.get("window_ns").unwrap().as_f64().unwrap(), 2e6, "--window 2ms");
    let models = doc.get("models").unwrap().as_arr().expect("models array");
    let shares = doc.get("shares").unwrap().as_f64().unwrap() as usize;
    assert!(!models.is_empty() && shares > 0);
    assert_eq!(doc.get("drift_trigger").unwrap().get("k").unwrap().as_f64().unwrap(), 3.0);
    assert_eq!(doc.get("drift_trigger").unwrap().get("n").unwrap().as_f64().unwrap(), 5.0);
    for win in series {
        assert_eq!(win.get("models").unwrap().as_arr().unwrap().len(), models.len());
        assert_eq!(win.get("share_busy_ns").unwrap().as_arr().unwrap().len(), shares);
    }

    // the CSV twin is the long format of the same data: a header plus one
    // model row and one share row per (window, name)
    let lines: Vec<&str> = csvs[0].lines().collect();
    assert!(
        lines[0].starts_with("window,start_ns,kind,name,arrivals,"),
        "unexpected csv header {:?}",
        lines[0]
    );
    assert_eq!(lines.len(), 1 + windows * (models.len() + shares));
    assert!(lines[1].contains(",model,"), "{:?}", lines[1]);
}

/// A flash crowd on a single model: every arrival is drained, so the run
/// is self-calibrating — the first (SLO-less) run reports the worst
/// windowed p99, and a second identical run declares an SLO 1 ns under
/// it. The whole-run p99 (nearest-rank, so strictly below the maximum
/// with enough samples) meets that bound while the spike's window does
/// not: the windowed detector fires where the whole-run aggregate stays
/// quiet.
const FLASH_ARGS: &[&str] = &[
    "serve",
    "--models",
    "scopenet",
    "--chiplets",
    "8",
    "--quantum",
    "4",
    "--samples",
    "4",
    "--batch",
    "4",
    "--seed",
    "3",
    "--arrival-rate",
    "2000",
    "--horizon",
    "0.05",
    "--rate-schedule",
    "flash",
    "--window",
    "1ms",
];

fn window_p99s(doc: &Json) -> Vec<f64> {
    doc.get("series")
        .unwrap()
        .as_arr()
        .expect("series array")
        .iter()
        .map(|w| w.get("models").unwrap().as_arr().unwrap()[0].get("p99_ns").unwrap())
        .map(|p| p.as_f64().unwrap())
        .collect()
}

#[test]
fn flash_crowd_drifts_in_a_window_while_the_whole_run_meets_the_slo() {
    // calibration run: no SLO declared, read the windowed p99 profile
    let cal_path = tmp("flash_cal.json");
    let cal_s = cal_path.display().to_string();
    let mut args = FLASH_ARGS.to_vec();
    args.extend(["--timeseries-out", &cal_s]);
    run_cli(&args);
    let cal = Json::parse(&std::fs::read_to_string(&cal_path).expect("calibration json"))
        .expect("calibration parse");
    let _ = std::fs::remove_file(&cal_path);
    let _ = std::fs::remove_file(cal_s.strip_suffix(".json").unwrap().to_string() + ".csv");
    let completions: f64 = cal
        .get("series")
        .unwrap()
        .as_arr()
        .unwrap()
        .iter()
        .map(|w| {
            w.get("models").unwrap().as_arr().unwrap()[0]
                .get("completions")
                .unwrap()
                .as_f64()
                .unwrap()
        })
        .sum();
    assert!(completions >= 100.0, "flash run too small to calibrate ({completions} completions)");
    let cal_p99s = window_p99s(&cal);
    let worst = cal_p99s.iter().cloned().fold(0.0, f64::max);
    assert!(worst >= 2.0, "degenerate windowed p99 {worst}");

    // drifting run: same seed and schedule, SLO 1 ns under the worst
    // window p99 — with one-window K-of-N sensitivity the spike's window
    // must trigger, yet the whole-run p99 must still meet the bound
    let slo_ms = format!("{}", (worst - 1.0) / 1e6);
    let ts_path = tmp("flash_slo.json");
    let ts_s = ts_path.display().to_string();
    let mut args = FLASH_ARGS.to_vec();
    args.extend(["--slo", &slo_ms, "--drift", "1/1", "--timeseries-out", &ts_s]);
    let out = run_cli(&args);
    assert!(out.contains("slo drift:"), "{out}");
    assert!(!out.contains("slo drift: 0 event"), "no drift detected:\n{out}");
    assert!(out.contains("SLO drift events"), "drift table missing:\n{out}");
    let hybrid = out
        .lines()
        .find(|l| l.trim_start().starts_with("hybrid ->"))
        .unwrap_or_else(|| panic!("no hybrid verdict line:\n{out}"));
    assert!(
        hybrid.contains("meets every declared SLO"),
        "whole-run p99 broke the calibrated SLO: {hybrid}"
    );

    let ts = Json::parse(&std::fs::read_to_string(&ts_path).expect("drift json"))
        .expect("drift parse");
    let _ = std::fs::remove_file(&ts_path);
    let _ = std::fs::remove_file(ts_s.strip_suffix(".json").unwrap().to_string() + ".csv");
    // the declared SLO changes nothing about the replay: the windowed
    // p99 profile is identical to the calibration run's
    assert_eq!(window_p99s(&ts), cal_p99s, "series drifted between calibration and SLO runs");
    let events = ts.get("drift_events").unwrap().as_arr().expect("drift_events array");
    assert!(!events.is_empty(), "no drift events in the export");
    for e in events {
        assert_eq!(e.get("slo_ns").unwrap().as_f64().unwrap(), worst - 1.0);
        assert!(e.get("worst_p99_ns").unwrap().as_f64().unwrap() > worst - 1.0);
    }
    let worst_event = events
        .iter()
        .map(|e| e.get("worst_p99_ns").unwrap().as_f64().unwrap())
        .fold(0.0, f64::max);
    assert_eq!(worst_event, worst, "the spike's window must carry the worst p99");
}

#[test]
fn malformed_timeseries_flags_name_the_offender() {
    let base = ["serve", "--models", "scopenet", "--chiplets", "8", "--samples", "4"];
    let cases: &[(&[&str], &[&str])] = &[
        (&["--rate-schedule", "30s:5000"], &["--rate-schedule", "30s:5000"]),
        (&["--rate-schedule", "0s:100,10ms:abc"], &["--rate-schedule", "10ms:abc"]),
        (&["--window", "0"], &["--window"]),
        (&["--window", "soon"], &["--window"]),
        (&["--drift", "5/3"], &["--drift", "N must be >= K"]),
        (&["--drift", "0/5"], &["--drift", "K must be >= 1"]),
        (&["--timeseries-out", "ts.parquet"], &["--timeseries-out", "twin"]),
        (
            &["--trace", "never_read.json", "--rate-schedule", "flash"],
            &["--rate-schedule has no effect with --trace"],
        ),
    ];
    for (extra, needles) in cases {
        let mut args = base.to_vec();
        args.extend_from_slice(extra);
        let err = run_cli_err(&args);
        for needle in *needles {
            assert!(err.contains(needle), "{extra:?}: {needle:?} not in {err:?}");
        }
    }
}
