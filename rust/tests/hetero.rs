//! Heterogeneous-package acceptance: the two properties that lock the
//! feature down.
//!
//! 1. **Degenerate equivalence** — a single-class spec (`big16`, with or
//!    without all-unit link overrides) is *bit-identical* to the uniform
//!    package everywhere: all four §V-A methods across the zoo, every
//!    `--threads` setting, the multi-model co-scheduler, and the CLI
//!    byte-for-byte (stdout, `--metrics-out`, `--trace-out`).
//! 2. **Exhaustive-placement ground truth** — on genuinely mixed
//!    packages the placed DP allocator returns the same split, rate, and
//!    per-model schedules as full enumeration over seeded random
//!    class/link maps, the span bound stays admissible against the real
//!    scheduler, and branch-and-bound pruning changes nothing.

use std::path::PathBuf;
use std::process::Command;

use scope::arch::{apply_hetero, McmConfig};
use scope::baselines::run_all;
use scope::config::SimOptions;
use scope::cost::{batch1_latency_lb_ns, share_rate_ub, SpanBound};
use scope::model::zoo;
use scope::model::WorkloadSet;
use scope::pipeline::{eval_segment_cached, EvalCache, EvalContext};
use scope::scope::{
    co_schedule, schedule_scope, search_segment, AllocatorKind, MultiModelResult,
    MultiOptions, SearchOptions,
};
use scope::storage::StoragePolicy;
use scope::util::rng::Rng;

fn run_cli(args: &[&str]) -> String {
    let out = Command::new(env!("CARGO_BIN_EXE_scope"))
        .args(args)
        .output()
        .expect("scope binary runs");
    assert!(
        out.status.success(),
        "scope {:?} failed: {}",
        args,
        String::from_utf8_lossy(&out.stderr)
    );
    String::from_utf8(out.stdout).expect("utf8 stdout")
}

/// Unique temp path per (process, label) so parallel tests never collide.
fn tmp(label: &str) -> PathBuf {
    std::env::temp_dir().join(format!("scope_hetero_{}_{label}", std::process::id()))
}

/// Stdout with the `trace:`/`metrics: wrote ...` lines removed — their
/// paths differ per invocation; everything else must match byte for byte.
fn strip_obs_lines(out: &str) -> String {
    out.lines()
        .filter(|l| !l.starts_with("trace: wrote") && !l.starts_with("metrics: wrote"))
        .map(|l| format!("{l}\n"))
        .collect()
}

/// A degenerate 16-chiplet package: the `big` preset is the base chiplet
/// unchanged, so this must behave as `paper_default(16)` bit for bit.
fn degenerate16(spec: &str) -> McmConfig {
    let mut mcm = McmConfig::paper_default(16);
    apply_hetero(&mut mcm, spec).unwrap();
    assert!(!mcm.is_hetero(), "{spec} must be degenerate (single class, unit links)");
    mcm
}

// ---------------------------------------------------------------------------
// 1. Degenerate equivalence
// ---------------------------------------------------------------------------

#[test]
fn degenerate_single_class_matches_uniform_across_the_zoo() {
    // Debug formatting of f64 is shortest-roundtrip, so equal Debug
    // strings of two MethodResults mean bit-equal schedules and evals.
    let sim = SimOptions { samples: 8, threads: 1, ..Default::default() };
    let uni = McmConfig::paper_default(16);
    let het = degenerate16("big16");
    for name in zoo::NAMES {
        let net = zoo::by_name(name).unwrap();
        let want = format!("{:?}", run_all(&net, &uni, &sim));
        let got = format!("{:?}", run_all(&net, &het, &sim));
        assert_eq!(want, got, "{name}: big16 drifted from the uniform package");
    }
}

#[test]
fn all_unit_link_overrides_are_dropped_and_equivalent() {
    // Scales of exactly 1.0 are the uniform mesh — the spec parser drops
    // the whole override list rather than storing a no-op that would
    // perturb cache keys.
    let het = degenerate16("big16/xcol1=1.0,xrow0=1.0");
    let net = zoo::by_name("alexnet").unwrap();
    let sim = SimOptions { samples: 8, threads: 1, ..Default::default() };
    let want = format!("{:?}", run_all(&net, &McmConfig::paper_default(16), &sim));
    assert_eq!(want, format!("{:?}", run_all(&net, &het, &sim)));
}

#[test]
fn degenerate_equivalence_holds_at_every_thread_count() {
    let net = zoo::by_name("resnet50").unwrap();
    let uni = McmConfig::paper_default(16);
    let het = degenerate16("big16");
    let mut first: Option<String> = None;
    for threads in [1usize, 2, 8] {
        let sim = SimOptions { samples: 8, threads, ..Default::default() };
        let want = format!("{:?}", schedule_scope(&net, &uni, &sim));
        let got = format!("{:?}", schedule_scope(&net, &het, &sim));
        assert_eq!(want, got, "threads={threads}: big16 drifted from uniform");
        // the engine's own guarantee: bit-identical at every thread count
        match &first {
            None => first = Some(got),
            Some(f) => assert_eq!(*f, got, "threads={threads} drifted from threads=1"),
        }
    }
}

#[test]
fn degenerate_multi_model_matches_uniform_for_both_allocators() {
    let set = WorkloadSet::parse("alexnet:2,scopenet").unwrap();
    let sim = SimOptions { samples: 4, threads: 1, ..Default::default() };
    let uni = McmConfig::paper_default(8);
    let mut het = McmConfig::paper_default(8);
    apply_hetero(&mut het, "big8").unwrap();
    for allocator in [AllocatorKind::Dp, AllocatorKind::Exhaustive] {
        let mopts = MultiOptions { allocator, share_quantum: 4, ..Default::default() };
        let want = format!("{:?}", co_schedule(&set, &uni, &sim, &mopts));
        let got = format!("{:?}", co_schedule(&set, &het, &sim, &mopts));
        assert_eq!(want, got, "{allocator:?}: big8 co-schedule drifted from uniform");
    }
}

#[test]
fn cli_search_is_byte_identical_with_artifacts() {
    // The acceptance bar: stdout AND both artifact files byte-identical
    // between the uniform package and `--hetero big16`.
    let base: &[&str] =
        &["search", "--net", "alexnet", "--chiplets", "16", "--samples", "4"];
    let mut outs: Vec<(String, String, String)> = Vec::new();
    for (label, hetero) in [("uni", None), ("het", Some("big16"))] {
        let t_path = tmp(&format!("search_{label}_t.json"));
        let m_path = tmp(&format!("search_{label}_m.json"));
        let (t_s, m_s) = (t_path.display().to_string(), m_path.display().to_string());
        let mut args = base.to_vec();
        args.extend(["--trace-out", &t_s, "--metrics-out", &m_s]);
        if let Some(spec) = hetero {
            args.extend(["--hetero", spec]);
        }
        let out = run_cli(&args);
        outs.push((
            strip_obs_lines(&out),
            std::fs::read_to_string(&t_path).expect("trace file"),
            std::fs::read_to_string(&m_path).expect("metrics file"),
        ));
        let _ = std::fs::remove_file(&t_path);
        let _ = std::fs::remove_file(&m_path);
    }
    assert_eq!(outs[0].0, outs[1].0, "--hetero big16 changed search stdout");
    assert_eq!(outs[0].1, outs[1].1, "--hetero big16 changed the trace file");
    assert_eq!(outs[0].2, outs[1].2, "--hetero big16 changed the metrics file");
}

#[test]
fn cli_multi_and_serve_are_byte_identical_on_degenerate_specs() {
    let multi: &[&str] = &[
        "multi", "--models", "scopenet,scopenet:2", "--chiplets", "8", "--quantum",
        "4", "--samples", "4",
    ];
    let serve: &[&str] = &[
        "serve", "--models", "serving_mix", "--seed", "7", "--chiplets", "16",
        "--quantum", "8", "--samples", "4", "--batch", "2", "--arrival-rate", "40",
        "--horizon", "0.05",
    ];
    for (cmd, spec) in [(multi, "big8"), (serve, "big16")] {
        let want = run_cli(cmd);
        let mut args = cmd.to_vec();
        args.extend(["--hetero", spec]);
        assert_eq!(want, run_cli(&args), "--hetero {spec} changed {} stdout", cmd[0]);
    }
}

// ---------------------------------------------------------------------------
// 2. Mixed packages: ground truth, admissibility, pruning
// ---------------------------------------------------------------------------

/// A seeded random *mixed* 8-chiplet spec: two or three classes in random
/// order, sometimes with a slow cross-reticle column link.
fn random_mixed_spec8(rng: &mut Rng) -> String {
    let mut names = ["big", "little", "micro"];
    rng.shuffle(&mut names);
    let a = rng.usize_in(1, 7); // 1..=6, so b = 8 - a >= 2
    let mut spec = if rng.bool_with(0.5) || a >= 6 {
        format!("{}{}{}{}", names[0], a, names[1], 8 - a)
    } else {
        let c = rng.usize_in(1, 8 - a); // leaves the middle class >= 1
        format!("{}{}{}{}{}{}", names[0], a, names[1], 8 - a - c, names[2], c)
    };
    match rng.gen_range(3) {
        0 => spec.push_str("/xcol0=0.5"),
        1 => spec.push_str("/xcol0=0.25"),
        _ => {}
    }
    spec
}

/// The fields a DP-vs-exhaustive comparison may look at: everything except
/// `allocator` (which records the kind) and `evals` (the two allocators
/// demand the (model, offset, share) surface in different orders).
fn placement_signature(r: &MultiModelResult) -> String {
    let outcomes: Vec<String> = r
        .outcomes
        .iter()
        .map(|o| format!("{}:{} {:?}", o.name, o.share, o.result))
        .collect();
    format!(
        "rate={:016x} total={:016x} tm={:016x} used={} err={:?} outcomes={outcomes:?}",
        r.rate.to_bits(),
        r.total_throughput.to_bits(),
        r.tm_rate.to_bits(),
        r.used_chiplets,
        r.error,
    )
}

#[test]
fn placed_dp_matches_exhaustive_ground_truth_on_random_packages() {
    let set = WorkloadSet::parse("alexnet:2,scopenet").unwrap();
    let sim = SimOptions { samples: 4, threads: 1, ..Default::default() };
    let mut rng = Rng::new(9);
    for trial in 0..6 {
        let spec = random_mixed_spec8(&mut rng);
        let mut mcm = McmConfig::paper_default(8);
        apply_hetero(&mut mcm, &spec).unwrap();
        assert!(mcm.is_hetero(), "trial {trial}: {spec} must be mixed");
        let run = |allocator: AllocatorKind| {
            let mopts =
                MultiOptions { allocator, share_quantum: 2, ..Default::default() };
            co_schedule(&set, &mcm, &sim, &mopts)
        };
        let dp = run(AllocatorKind::Dp);
        let ex = run(AllocatorKind::Exhaustive);
        assert!(dp.error.is_none(), "trial {trial} ({spec}): {:?}", dp.error);
        assert_eq!(
            placement_signature(&dp),
            placement_signature(&ex),
            "trial {trial}: DP placement drifted from exhaustive on {spec}"
        );
        assert_eq!(dp.pruned_pairs, 0, "placed tables are never pre-filtered");
    }
}

#[test]
fn span_bound_stays_admissible_on_mixed_packages() {
    // The hetero analogue of cost/bound.rs's load-bearing property: over
    // every schedulable alexnet span on a mixed slow-linked package, the
    // lower bound never exceeds the exact evaluated latency.
    let net = zoo::by_name("alexnet").unwrap();
    let mut mcm = McmConfig::paper_default(16);
    apply_hetero(&mut mcm, "big8little8/xcol1=0.5").unwrap();
    assert!(mcm.is_hetero());
    let sim = SimOptions { samples: 16, threads: 1, ..Default::default() };
    let b = SpanBound::new(&net, &mcm, sim.samples);
    let ctx = EvalContext {
        net: &net,
        mcm: &mcm,
        opts: &sim,
        policy: StoragePolicy::Distributed,
        dram_fallback: true,
    };
    let cache = EvalCache::new();
    let mut checked = 0usize;
    for lo in 0..net.len() {
        for hi in (lo + 1)..=net.len() {
            let Some(found) =
                search_segment(&ctx, lo, hi, sim.samples, SearchOptions::default())
            else {
                continue;
            };
            let ev =
                eval_segment_cached(&ctx, &found.schedule, sim.samples, Some(&cache));
            if ev.error.is_some() {
                continue;
            }
            let exact = ev.preload_cycles + ev.pipeline_cycles;
            let lb = b.lower_bound(lo, hi);
            assert!(
                lb <= exact * (1.0 + 1e-9),
                "span [{lo},{hi}): hetero bound {lb} > exact {exact}"
            );
            checked += 1;
        }
    }
    assert!(checked > 0, "no schedulable span on the mixed package");
}

#[test]
fn share_bounds_assume_the_fastest_class() {
    // A share's slots are chosen by placement, so the analytic share
    // bounds must price the best case. `big` is the base chiplet, so on a
    // big/little mix they coincide bit-for-bit with the uniform bounds.
    let uni = McmConfig::paper_default(16);
    let mut mix = McmConfig::paper_default(16);
    apply_hetero(&mut mix, "little8big8").unwrap();
    let macs = 1e9;
    for share in [1usize, 4, 16] {
        assert_eq!(
            share_rate_ub(macs, share, &mix).to_bits(),
            share_rate_ub(macs, share, &uni).to_bits()
        );
        assert_eq!(
            batch1_latency_lb_ns(macs, share, &mix).to_bits(),
            batch1_latency_lb_ns(macs, share, &uni).to_bits()
        );
    }
}

#[test]
fn pruning_changes_nothing_on_mixed_packages() {
    // Branch-and-bound rests on bound admissibility; on a mixed package
    // with a slow link the pruned and unpruned searches must still pick
    // bit-identical schedules (only the sweep statistics may differ).
    let net = zoo::by_name("alexnet").unwrap();
    let mut mcm = McmConfig::paper_default(16);
    apply_hetero(&mut mcm, "big8little8/xcol1=0.5").unwrap();
    let run = |prune: bool| {
        let sim = SimOptions { samples: 8, threads: 1, prune, ..Default::default() };
        let r = schedule_scope(&net, &mcm, &sim);
        format!("{:?} {:?}", r.schedule, r.eval)
    };
    assert_eq!(run(true), run(false), "pruning altered a mixed-package schedule");
}
