//! Integration tests for the global DP segmenter: dominance over the
//! balanced-split sweep across the whole zoo, bit-identity across thread
//! counts, and agreement with exhaustive boundary enumeration — the
//! acceptance criteria of the boundary × schedule co-search.

use scope::arch::McmConfig;
use scope::baselines::schedule_segmented;
use scope::config::SimOptions;
use scope::dse::exhaustive::exhaustive_segmentations;
use scope::model::zoo;
use scope::pipeline::timeline::EvalContext;
use scope::scope::{
    schedule_scope, search_segment, search_segments_opts, SearchOptions, SegmenterKind,
    SegmenterOptions,
};
use scope::storage::StoragePolicy;

fn sim(segmenter: SegmenterKind, dp_window: usize) -> SimOptions {
    SimOptions { samples: 8, segmenter, dp_window, ..Default::default() }
}

#[test]
fn dp_never_worse_than_balanced_across_the_zoo() {
    // Every zoo network at two package scales, through the segmented
    // baseline's per-layer scheduler (the identical-allocator §V-A path —
    // cheap enough to sweep the deep ResNets in a test). The DP's window
    // contains the balanced seed, so it can only match or improve.
    let mut nets = zoo::paper_networks();
    nets.push(zoo::scopenet());
    for net in &nets {
        for chiplets in [16usize, 32] {
            let mcm = McmConfig::paper_default(chiplets);
            let bal = schedule_segmented(net, &mcm, &sim(SegmenterKind::Balanced, 1));
            if !bal.eval.is_valid() {
                continue; // nothing to dominate at this scale
            }
            let dp = schedule_segmented(net, &mcm, &sim(SegmenterKind::Dp, 1));
            assert!(
                dp.eval.is_valid(),
                "{}@{chiplets}: dp invalid where balanced is valid: {:?}",
                net.name,
                dp.eval.error
            );
            assert!(
                dp.throughput() >= bal.throughput() * 0.999,
                "{}@{chiplets}: dp {} < balanced {}",
                net.name,
                dp.throughput(),
                bal.throughput()
            );
        }
    }
}

#[test]
fn scope_dp_never_worse_than_balanced_at_two_scales() {
    // The full merged-pipeline scheduler as the span cost, on the nets
    // small enough to search repeatedly in a test.
    let settings =
        [("alexnet", [16usize, 64]), ("scopenet", [8, 16]), ("darknet19", [16, 64])];
    for (name, scales) in settings {
        let net = zoo::by_name(name).unwrap();
        for chiplets in scales {
            let mcm = McmConfig::paper_default(chiplets);
            let bal = schedule_scope(&net, &mcm, &sim(SegmenterKind::Balanced, 2));
            if !bal.eval.is_valid() {
                continue;
            }
            let dp = schedule_scope(&net, &mcm, &sim(SegmenterKind::Dp, 2));
            assert!(dp.eval.is_valid(), "{name}@{chiplets}: {:?}", dp.eval.error);
            assert!(
                dp.throughput() >= bal.throughput() * 0.999,
                "{name}@{chiplets}: dp {} < balanced {}",
                dp.throughput(),
                bal.throughput()
            );
        }
    }
}

#[test]
fn dp_segmented_baseline_is_bit_identical_across_threads() {
    // VGG16@16 forces ~9 segments, so the DP really runs; the span
    // prefetch fans across the pool but the result must not move.
    let net = zoo::vgg16();
    let mcm = McmConfig::paper_default(16);
    let serial = schedule_segmented(
        &net,
        &mcm,
        &SimOptions { threads: 1, ..sim(SegmenterKind::Dp, 2) },
    );
    assert!(serial.eval.is_valid(), "{:?}", serial.eval.error);
    for threads in [2usize, 8] {
        let par = schedule_segmented(
            &net,
            &mcm,
            &SimOptions { threads, ..sim(SegmenterKind::Dp, 2) },
        );
        assert_eq!(serial.schedule, par.schedule, "threads={threads}: schedule drifted");
        assert_eq!(
            serial.eval.total_cycles.to_bits(),
            par.eval.total_cycles.to_bits(),
            "threads={threads}: latency drifted"
        );
    }
}

#[test]
fn dp_matches_exhaustive_boundary_enumeration_on_alexnet() {
    // Ground truth: enumerate *every* boundary placement (1..=3 segments)
    // on AlexNet at the paper's smallest scale, with each span scheduled
    // by the real Algorithm-1 search. The unpruned DP must find the same
    // optimal total, bit for bit (identical left-associated accumulation).
    let net = zoo::alexnet();
    let mcm = McmConfig::paper_default(16);
    let opts = SimOptions { samples: 8, threads: 1, ..Default::default() };
    let ctx = EvalContext {
        net: &net,
        mcm: &mcm,
        opts: &opts,
        policy: StoragePolicy::Distributed,
        dram_fallback: true,
    };
    let provider = |lo: usize, hi: usize| {
        search_segment(&ctx, lo, hi, opts.samples, SearchOptions::default())
            .map(|s| (s.schedule, s.latency))
    };
    let dp = search_segments_opts(
        &net,
        1,
        3,
        usize::MAX,
        1,
        SegmenterOptions { kind: SegmenterKind::Dp, dp_window: 0, ..SegmenterOptions::default() },
        &provider,
    )
    .expect("dp result");
    let ex = exhaustive_segmentations(net.len(), 1, 3, usize::MAX, |lo, hi| {
        provider(lo, hi).map(|(_, lat)| lat)
    })
    .expect("exhaustive result");
    assert_eq!(
        dp.total_latency.to_bits(),
        ex.1.to_bits(),
        "dp {} vs exhaustive {}",
        dp.total_latency,
        ex.1
    );
    // boundary sets may differ only on exact latency ties; both must
    // re-sum to the optimal total
    let resum = |bounds: &[usize]| {
        bounds.windows(2).fold(0.0f64, |acc, w| {
            acc + provider(w[0], w[1]).expect("winning span schedulable").1
        })
    };
    assert_eq!(resum(&dp.bounds).to_bits(), ex.1.to_bits());
    assert_eq!(resum(&ex.0).to_bits(), ex.1.to_bits());
}
