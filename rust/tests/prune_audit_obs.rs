//! `SCOPE_PRUNE_AUDIT=1` surfaces its re-verification work through the
//! metrics registry: the audited span count and the loosest relative
//! bound slack observed. Lives in its own integration-test binary so the
//! env var never leaks into other tests' processes.

use scope::arch::McmConfig;
use scope::config::SimOptions;
use scope::model::zoo;
use scope::obs::Registry;
use scope::scope::{schedule_scope, SegmenterKind};

#[test]
fn audited_run_reports_span_count_and_bound_slack() {
    std::env::set_var("SCOPE_PRUNE_AUDIT", "1");
    let net = zoo::by_name("alexnet").unwrap();
    let mcm = McmConfig::paper_default(16);
    let sim = SimOptions {
        samples: 4,
        threads: 1,
        segmenter: SegmenterKind::Dp,
        ..SimOptions::default()
    };
    let r = schedule_scope(&net, &mcm, &sim);
    assert!(r.schedule.is_some(), "alexnet must schedule: {:?}", r.eval.error);

    let audited = Registry::global().counter("scope_prune_audit_spans").get();
    assert!(audited > 0, "SCOPE_PRUNE_AUDIT=1 + dp segmenter must audit spans");
    let summary = scope::obs::prune_audit_summary().expect("summary for an audited run");
    assert!(summary.contains(&audited.to_string()), "{summary}");
    // admissible bounds sit at or under the exact latency, so the
    // relative slack (lat - bound) / lat stays within [0, 1]
    let slack = Registry::global().gauge("scope_prune_audit_max_rel_slack").get();
    assert!((0.0..=1.0).contains(&slack), "relative slack out of range: {slack}");
}
