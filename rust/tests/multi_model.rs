//! Multi-model co-scheduling + cache-store acceptance tests (PR 4):
//!
//! * a two-model co-schedule (weighted throughput) beats the
//!   time-multiplexed sequential baseline on a zoo pair,
//! * the weighted-throughput DP matches the exhaustive chiplet-split
//!   ground truth bit-for-bit,
//! * batched (store-backed) runs are bit-identical to
//!   one-process-per-model runs at 1/2/8 threads, and
//! * a batched sweep pays each distinct span once, reporting >0
//!   cross-sweep cache hits.
//!
//! Store-stat assertions use distinctive `samples` values so their store
//! keys cannot collide with other tests sharing the process-wide store.

use scope::arch::McmConfig;
use scope::config::SimOptions;
use scope::model::WorkloadSet;
use scope::scope::{co_schedule, schedule_scope, AllocatorKind, MultiOptions, SegmenterKind};

fn sim(samples: u64, threads: usize, cache_store: bool) -> SimOptions {
    SimOptions { samples, threads, cache_store, ..Default::default() }
}

#[test]
fn co_schedule_beats_time_multiplexed_on_a_zoo_pair() {
    // Two AlexNets on 64 chiplets: per-model scaling is sublinear at this
    // scale (the paper's Fig. 9 regime), so spatial sharing — e.g. 32+32,
    // each keeping well over half its full-package throughput — must beat
    // round-robining the whole package.
    let set = WorkloadSet::parse("alexnet,alexnet").unwrap();
    let mopts = MultiOptions { share_quantum: 16, ..Default::default() };
    let r = co_schedule(&set, &McmConfig::paper_default(64), &sim(16, 0, true), &mopts);
    assert!(r.is_valid(), "{:?}", r.error);
    assert!(r.rate > 0.0 && r.tm_rate > 0.0);
    assert!(
        r.rate > r.tm_rate,
        "co-schedule {} must beat time-multiplexed {} (shares {:?})",
        r.rate,
        r.tm_rate,
        r.outcomes.iter().map(|o| o.share).collect::<Vec<_>>()
    );
    assert_eq!(r.speedup_vs_tm().map(|x| x > 1.0), Some(true));
    assert!(r.used_chiplets <= 64);
    assert!(r.utilization() > 0.0 && r.utilization() <= 1.0);
    // every model is actually served at the reported rate
    for o in &r.outcomes {
        assert!(o.result.eval.is_valid(), "{}", o.name);
        assert!(o.result.throughput() / o.weight >= r.rate * (1.0 - 1e-12), "{}", o.name);
    }
}

#[test]
fn dp_allocator_matches_exhaustive_ground_truth_bit_for_bit() {
    // A small mixed set where full enumeration is cheap: the DP must land
    // on the same optimal mix rate (bit-identical — both allocators fold
    // the same pure throughput table through exact min/max) and the same
    // chiplet usage.
    let set = WorkloadSet::parse("alexnet:1,scopenet:2").unwrap();
    let s = sim(8, 0, true);
    let mk = |allocator| MultiOptions {
        allocator,
        method: "scope".to_string(),
        share_quantum: 4,
    };
    let mcm = McmConfig::paper_default(16);
    let dp = co_schedule(&set, &mcm, &s, &mk(AllocatorKind::Dp));
    let ex = co_schedule(&set, &mcm, &s, &mk(AllocatorKind::Exhaustive));
    assert!(dp.is_valid(), "{:?}", dp.error);
    assert!(ex.is_valid(), "{:?}", ex.error);
    assert_eq!(
        dp.rate.to_bits(),
        ex.rate.to_bits(),
        "dp {} vs exhaustive {}",
        dp.rate,
        ex.rate
    );
    assert_eq!(dp.used_chiplets, ex.used_chiplets);
    assert_eq!(dp.total_throughput.to_bits(), ex.total_throughput.to_bits());
    assert_eq!(dp.tm_rate.to_bits(), ex.tm_rate.to_bits());
}

#[test]
fn batched_equals_unbatched_at_every_thread_count() {
    // The store and the outer fan-out may change *how* the table is
    // computed, never *what* it holds: shares, mix rate, and every
    // per-model schedule must be bit-identical across store on/off and
    // 1/2/8 worker threads.
    let set = WorkloadSet::parse("scopenet,alexnet").unwrap();
    let mcm = McmConfig::paper_default(16);
    let mopts = MultiOptions { share_quantum: 8, ..Default::default() };
    let base = co_schedule(&set, &mcm, &sim(12, 1, false), &mopts);
    assert!(base.is_valid(), "{:?}", base.error);
    for threads in [1usize, 2, 8] {
        for store in [false, true] {
            let got = co_schedule(&set, &mcm, &sim(12, threads, store), &mopts);
            assert!(got.is_valid(), "threads={threads} store={store}");
            assert_eq!(
                base.rate.to_bits(),
                got.rate.to_bits(),
                "threads={threads} store={store}"
            );
            assert_eq!(base.used_chiplets, got.used_chiplets);
            assert_eq!(base.tm_rate.to_bits(), got.tm_rate.to_bits());
            for (a, b) in base.outcomes.iter().zip(&got.outcomes) {
                assert_eq!(a.share, b.share, "threads={threads} store={store}");
                assert_eq!(
                    a.result.eval.total_cycles.to_bits(),
                    b.result.eval.total_cycles.to_bits(),
                    "threads={threads} store={store} model={}",
                    a.name
                );
                assert_eq!(a.result.schedule, b.result.schedule, "model={}", a.name);
            }
        }
    }
}

#[test]
fn batched_sweep_pays_each_span_once_and_reports_cross_hits() {
    // Two passes of the same (net, platform, method, sim) with the store
    // on — the batched-sweep shape: the second sweep's spans are all
    // carried from the first (zero scheduler calls), counted as
    // cross-sweep hits, and the result stays bit-identical to a
    // store-less run.
    let net = scope::model::zoo::alexnet();
    let mcm = McmConfig::paper_default(16);
    let plain = schedule_scope(&net, &mcm, &sim(28, 0, false));
    let first = schedule_scope(&net, &mcm, &sim(28, 0, true));
    let second = schedule_scope(&net, &mcm, &sim(28, 0, true));
    for r in [&first, &second] {
        assert!(r.eval.is_valid(), "{:?}", r.eval.error);
        assert_eq!(plain.eval.total_cycles.to_bits(), r.eval.total_cycles.to_bits());
        assert_eq!(plain.schedule, r.schedule);
    }
    let s1 = first.segmenter.as_ref().expect("report").stats;
    let s2 = second.segmenter.as_ref().expect("report").stats;
    assert!(s1.misses > 0, "first sweep must schedule spans: {s1:?}");
    assert_eq!(s1.cross_hits, 0, "nothing to carry on a cold store: {s1:?}");
    assert_eq!(s2.misses, 0, "every span must be carried: {s2:?}");
    assert!(s2.cross_hits > 0, "{s2:?}");
    assert_eq!(
        s1.hits + s1.misses,
        s2.hits + s2.misses,
        "identical sweeps make identical span requests"
    );
}

#[test]
fn store_backed_dp_segmenter_is_thread_invariant() {
    // The store key deliberately excludes the thread count (results are
    // bit-identical at every count), so runs at different thread counts
    // *share* spans — and must still agree exactly, DP segmenter included.
    let net = scope::model::zoo::alexnet();
    let mcm = McmConfig::paper_default(16);
    let mk = |threads| SimOptions {
        samples: 44,
        threads,
        cache_store: true,
        segmenter: SegmenterKind::Dp,
        ..Default::default()
    };
    let base = schedule_scope(&net, &mcm, &mk(1));
    assert!(base.eval.is_valid(), "{:?}", base.eval.error);
    for threads in [2usize, 8] {
        let got = schedule_scope(&net, &mcm, &mk(threads));
        assert_eq!(base.schedule, got.schedule, "threads={threads}");
        assert_eq!(
            base.eval.total_cycles.to_bits(),
            got.eval.total_cycles.to_bits(),
            "threads={threads}"
        );
    }
}

#[test]
fn serving_mix_co_schedules_end_to_end() {
    // The built-in mixed chain+DAG set (resnet50_dag + googlenet +
    // alexnet) runs end to end on a small package with a coarse grid.
    // The per-model method is `sequential` — the cheap §V-A scheduler —
    // so the deep DAGs stay fast in a debug test; the full Scope search
    // over this set is the CI release smoke's job.
    let set = WorkloadSet::serving_mix();
    let mopts = MultiOptions {
        method: "sequential".to_string(),
        share_quantum: 8,
        ..Default::default()
    };
    let r = co_schedule(&set, &McmConfig::paper_default(32), &sim(4, 0, true), &mopts);
    assert!(r.is_valid(), "{:?}", r.error);
    assert_eq!(r.outcomes.len(), 3);
    assert!(r.rate > 0.0);
    assert!(r.used_chiplets <= 32);
    for o in &r.outcomes {
        assert!(o.share >= 8, "{}: grid share", o.name);
        assert!(o.result.eval.is_valid(), "{}: {:?}", o.name, o.result.eval.error);
    }
    let snap = r.store.expect("store stats on");
    assert!(snap.span_checkouts > 0);
}
