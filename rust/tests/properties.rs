//! Property-based tests (in-crate xorshift driver — proptest is not in
//! the offline vendor set): invariants of the cost models, schedule
//! types, DSE algorithms, and storage planner over randomized inputs.

use scope::arch::{ChipletConfig, McmConfig, Mesh};
use scope::config::SimOptions;
use scope::cost::{comp_cycles, dram_transfer, shard, utilization};
use scope::dse::{exhaustive_segment, ExhaustiveOptions};
use scope::model::tile::lower_segment;
use scope::model::{Layer, Network};
use scope::pipeline::fused::{fused_candidate, overflow_bytes};
use scope::pipeline::schedule::{ExecMode, ExecModeChoice, Partition, Schedule, SegmentSchedule};
use scope::pipeline::timeline::{eval_schedule, eval_segment, EvalContext};
use scope::scope::cmt::gen_cmt;
use scope::scope::region_alloc::proportional_allocate;
use scope::scope::segmenter::balanced_split;
use scope::scope::{search_segment, SearchOptions};
use scope::storage::{plan_cluster, LayerResidency, StoragePolicy};
use scope::util::rng::Rng;

const CASES: usize = 60;

/// Random conv layer with valid geometry.
fn rand_layer(rng: &mut Rng, idx: usize, hin: u64, cin: u64) -> Layer {
    let k = *[1u64, 3, 5].get(rng.usize_in(0, 3)).unwrap();
    let cout = 8 << rng.usize_in(0, 4); // 8..128
    let pad = k / 2;
    Layer::conv(&format!("l{idx}"), hin, hin, cin, cout, k, 1, pad)
}

/// Random chain network (spatial size halves occasionally via pools).
fn rand_network(rng: &mut Rng) -> Network {
    let depth = rng.usize_in(2, 9);
    let mut h = 16u64 << rng.usize_in(0, 2); // 16/32/64
    let mut c = 3u64;
    let mut layers = Vec::new();
    for i in 0..depth {
        let mut l = rand_layer(rng, i, h, c);
        if h >= 8 && rng.bool_with(0.3) {
            l = l.with_pool(2, 2);
        }
        c = l.cout;
        h = l.hout();
        layers.push(l);
    }
    Network::new("rand", (layers[0].hin, layers[0].win, 3), layers)
}

#[test]
fn prop_comp_cycles_monotone_in_chiplets() {
    // More chiplets never increase the per-chiplet compute time.
    let mut rng = Rng::new(1);
    let chip = ChipletConfig::paper_default();
    for i in 0..CASES {
        let l = rand_layer(&mut rng, i, 16, 16);
        for p in [Partition::Isp, Partition::Wsp] {
            let mut last = f64::INFINITY;
            for r in [1u64, 2, 4, 8, 16, 32] {
                let c = comp_cycles(&l, p, r, &chip);
                assert!(c <= last + 1e-9, "{l:?} {p:?} r={r}: {c} > {last}");
                assert!(c >= 1.0, "at least one cycle");
                last = c;
            }
        }
    }
}

#[test]
fn prop_utilization_bounded_and_exact_at_r1() {
    let mut rng = Rng::new(2);
    let chip = ChipletConfig::paper_default();
    for i in 0..CASES {
        let l = rand_layer(&mut rng, i, 16, 32);
        for p in [Partition::Isp, Partition::Wsp] {
            for r in [1u64, 3, 7, 16] {
                let u = utilization(&l, p, r, &chip);
                assert!((0.0..=1.0 + 1e-9).contains(&u), "u={u}");
            }
        }
        // shard at r=1 must cover the whole layer
        let s = shard(&l, Partition::Isp, 1);
        assert_eq!(s.co, l.cout);
        assert_eq!(s.px, l.pixels());
    }
}

#[test]
fn prop_shard_work_conservation() {
    // r * shard work ≥ total work (ceil waste only ever adds).
    let mut rng = Rng::new(3);
    for i in 0..CASES {
        let l = rand_layer(&mut rng, i, 16, 16);
        for p in [Partition::Isp, Partition::Wsp] {
            for r in [2u64, 3, 5, 8] {
                let s = shard(&l, p, r);
                assert!(s.co * s.px * r >= l.cout * l.pixels() / 2, "gross sanity");
                match p {
                    Partition::Isp => assert!(s.co * r >= l.cout),
                    Partition::Wsp => assert!(s.px * r >= l.pixels()),
                }
            }
        }
    }
}

#[test]
fn prop_cmt_rows_are_nested_partitions() {
    let mut rng = Rng::new(4);
    for _ in 0..CASES {
        let net = rand_network(&mut rng);
        let cmt = gen_cmt(&net.layers, 0, net.len());
        for n in 1..=net.len() {
            let b = cmt.bounds(n);
            assert_eq!(b.len(), n + 1);
            assert!(b.windows(2).all(|w| w[0] < w[1]));
        }
        for n in 2..=net.len() {
            let coarse = cmt.bounds(n - 1);
            let fine = cmt.bounds(n);
            assert!(coarse.iter().all(|x| fine.contains(x)));
        }
    }
}

#[test]
fn prop_proportional_allocate_exact_and_positive() {
    let mut rng = Rng::new(5);
    for _ in 0..CASES * 4 {
        let n = rng.usize_in(1, 9);
        let loads: Vec<u64> = (0..n).map(|_| rng.gen_range(1000) + 1).collect();
        let c = rng.usize_in(n, n + 60);
        let a = proportional_allocate(&loads, c).unwrap();
        assert_eq!(a.iter().sum::<usize>(), c);
        assert!(a.iter().all(|&x| x >= 1));
        // heavier loads never get fewer chiplets than a load 10x smaller
        for i in 0..n {
            for j in 0..n {
                if loads[i] >= loads[j] * 10 {
                    assert!(a[i] >= a[j], "loads {loads:?} alloc {a:?}");
                }
            }
        }
    }
}

#[test]
fn prop_balanced_split_covers_and_bounds() {
    let mut rng = Rng::new(6);
    for _ in 0..CASES {
        let net = rand_network(&mut rng);
        for s in 1..=net.len().min(4) {
            let b = balanced_split(&net, s);
            assert_eq!(*b.first().unwrap(), 0);
            assert_eq!(*b.last().unwrap(), net.len());
            assert!(b.windows(2).all(|w| w[0] < w[1]));
            assert!(b.len() - 1 <= s);
        }
    }
}

#[test]
fn prop_storage_plan_fits_capacity() {
    let mut rng = Rng::new(7);
    for _ in 0..CASES {
        let net = rand_network(&mut rng);
        let parts: Vec<Partition> = net
            .layers
            .iter()
            .map(|_| if rng.bool_with(0.5) { Partition::Wsp } else { Partition::Isp })
            .collect();
        for policy in [StoragePolicy::Replicated, StoragePolicy::Distributed] {
            for cap_kb in [64u64, 256, 1024] {
                let r = 1 + rng.gen_range(8);
                let plan =
                    plan_cluster(&net.layers, &parts, r, policy, cap_kb * 1024);
                assert!(
                    plan.footprint <= cap_kb * 1024,
                    "footprint {} > cap {}",
                    plan.footprint,
                    cap_kb * 1024
                );
                assert_eq!(plan.residency.len(), net.len());
                // If everything fits fully replicated, the distributed
                // planner must also keep everything on-chip (its Resident
                // state has identical demand), i.e. it can only help.
                if policy == StoragePolicy::Distributed {
                    let repl = plan_cluster(
                        &net.layers,
                        &parts,
                        r,
                        StoragePolicy::Replicated,
                        cap_kb * 1024,
                    );
                    if repl.streamed_count() == 0 {
                        assert_eq!(plan.streamed_count(), 0);
                    }
                    // and a fully-on-chip distributed plan never uses more
                    // bytes than the replicated one
                    if plan.fully_on_chip() && repl.fully_on_chip() {
                        assert!(plan.footprint <= repl.footprint);
                    }
                }
            }
        }
    }
}

#[test]
fn prop_eval_is_finite_and_positive_for_valid_schedules() {
    let mut rng = Rng::new(8);
    let opts = SimOptions { samples: 8, ..Default::default() };
    for _ in 0..CASES / 2 {
        let net = rand_network(&mut rng);
        let chiplets = 16usize;
        let mcm = McmConfig::paper_default(chiplets);
        let ctx = EvalContext {
            net: &net,
            mcm: &mcm,
            opts: &opts,
            policy: StoragePolicy::Distributed,
            dram_fallback: true,
        };
        // random contiguous clustering + random regions summing to C
        let l = net.len();
        let n = rng.usize_in(1, l.min(chiplets) + 1);
        let cmt = gen_cmt(&net.layers, 0, l);
        let bounds = cmt.bounds(n).to_vec();
        let loads: Vec<u64> = (0..n)
            .map(|j| (bounds[j]..bounds[j + 1]).map(|k| net.layers[k].macs()).sum())
            .collect();
        let regions = proportional_allocate(&loads, chiplets).unwrap();
        let partitions: Vec<Partition> = (0..l)
            .map(|_| if rng.bool_with(0.5) { Partition::Wsp } else { Partition::Isp })
            .collect();
        let sched = Schedule {
            method: "rand".into(),
            segments: vec![SegmentSchedule {
                lo: 0,
                hi: l,
                bounds,
                regions,
                partitions,
                exec_mode: ExecMode::Pipeline,
            }],
        };
        let ev = eval_schedule(&ctx, &sched);
        assert!(ev.is_valid(), "{:?}", ev.error);
        assert!(ev.total_cycles.is_finite() && ev.total_cycles > 0.0);
        assert!(ev.throughput > 0.0);
        assert!(ev.energy.total_pj() > 0.0);
        // pipeline arithmetic: Equ. 2 exactly
        let seg = &ev.segments[0];
        let expect = (opts.samples as f64 + seg.clusters.len() as f64 - 1.0)
            * seg.stage_cycles;
        assert!((seg.pipeline_cycles - expect).abs() < 1e-6);
    }
}

#[test]
fn prop_search_never_beaten_by_exhaustive_and_lands_near_top() {
    // On random small nets, Algorithm 1 must (a) never beat the true
    // optimum, (b) land within 10% of it — the quantitative version of
    // the Fig. 8 claim at property scale.
    let mut rng = Rng::new(9);
    let opts = SimOptions { samples: 8, ..Default::default() };
    for case in 0..6 {
        let net = loop {
            let n = rand_network(&mut rng);
            if n.len() <= 5 {
                break n;
            }
        };
        let chiplets = 6usize;
        let mcm = McmConfig::paper_default(chiplets);
        let ctx = EvalContext {
            net: &net,
            mcm: &mcm,
            opts: &opts,
            policy: StoragePolicy::Distributed,
            dram_fallback: true,
        };
        let ex = exhaustive_segment(&ctx, 0, net.len(), 8, ExhaustiveOptions::default());
        let Some(found) = search_segment(&ctx, 0, net.len(), 8, SearchOptions::default())
        else {
            panic!("case {case}: search found nothing");
        };
        assert!(
            found.latency >= ex.best_latency * (1.0 - 1e-9),
            "case {case}: search {} beat exhaustive {}",
            found.latency,
            ex.best_latency
        );
        assert!(
            found.latency <= ex.best_latency * 1.10,
            "case {case}: search {} >10% off optimum {}",
            found.latency,
            ex.best_latency
        );
    }
}

#[test]
fn prop_fused_dram_never_exceeds_pipeline_beyond_declared_overflow() {
    // For the same span on the same region, the fused evaluator's DRAM
    // traffic is *exactly* the same-geometry pipeline evaluation's DRAM
    // (identical residency plan → identical weight streaming) plus the
    // declared activation-overflow round trip. In particular it never
    // reports more DRAM than pipeline whenever its live set fits the
    // region's SRAM share — the overflow surcharge is the only extra.
    let mut rng = Rng::new(11);
    for _ in 0..CASES / 3 {
        let net = rand_network(&mut rng);
        let chiplets = 16usize;
        let shrink = *[1u64, 4, 64].get(rng.usize_in(0, 3)).unwrap();
        let mut mcm = McmConfig::paper_default(chiplets);
        mcm.chiplet.global_buf /= shrink;
        let tile_rows = 1 + rng.gen_range(8);
        let opts = SimOptions { samples: 4, tile_rows, ..Default::default() };
        let ctx = EvalContext {
            net: &net,
            mcm: &mcm,
            opts: &opts,
            policy: StoragePolicy::Distributed,
            dram_fallback: true,
        };
        let lo = rng.usize_in(0, net.len());
        let hi = rng.usize_in(lo + 1, net.len() + 1);
        let fused = fused_candidate(&net, &mcm, lo, hi, chiplets);
        let mut pipe = fused.clone();
        pipe.exec_mode = ExecMode::Pipeline;
        let ev_f = eval_segment(&ctx, &fused, 4);
        let ev_p = eval_segment(&ctx, &pipe, 4);
        assert!(ev_f.error.is_none(), "{:?}", ev_f.error);
        assert!(ev_p.error.is_none(), "{:?}", ev_p.error);
        let dram = |ev: &scope::pipeline::timeline::SegmentEval| ev.clusters[0].energy.dram_pj;
        let g = lower_segment(&net, lo, hi, tile_rows);
        let over = overflow_bytes(&g, chiplets as u64 * mcm.chiplet.global_buf);
        let surcharge = if over > 0 {
            dram_transfer((2 * over) as f64, &mcm.dram, mcm.chiplet.freq_hz, 1.0).energy_pj
        } else {
            0.0
        };
        let (f, p) = (dram(&ev_f), dram(&ev_p));
        assert!(f >= p - 1e-9, "[{lo},{hi}) ÷{shrink}: fused dram {f} < pipeline {p}");
        assert!(
            (f - (p + surcharge)).abs() <= 1e-9 * (p + surcharge).max(1.0),
            "[{lo},{hi}) ÷{shrink}: fused dram {f} != pipeline {p} + overflow {surcharge}"
        );
        if over == 0 {
            assert!(
                f <= p + 1e-9,
                "[{lo},{hi}) ÷{shrink}: live set fits but fused dram {f} > pipeline {p}"
            );
        }
        // the no-bubble trade: fused also never charges NoP comm phases
        assert!(ev_f.clusters[0].energy.nop_pj <= ev_p.clusters[0].energy.nop_pj + 1e-9);
    }
}

#[test]
fn prop_tile_lowering_is_exact_over_tile_sizes() {
    // Σ tile MACs / output bytes per layer equal the layer totals for any
    // tile size — lowering redistributes work, it never creates or drops
    // any (the seeded sweep the fused evaluator's costs rest on).
    let mut rng = Rng::new(12);
    for _ in 0..CASES / 2 {
        let net = rand_network(&mut rng);
        let lo = rng.usize_in(0, net.len());
        let hi = rng.usize_in(lo + 1, net.len() + 1);
        for tile_rows in [1u64, 2, 3, 5, 8, 1 + rng.gen_range(61)] {
            let g = lower_segment(&net, lo, hi, tile_rows);
            g.validate(&net).unwrap_or_else(|e| {
                panic!("[{lo},{hi}) tile_rows={tile_rows}: {e}");
            });
            for k in lo..hi {
                let (s, e) = g.tiles_of(k);
                let macs: u64 = g.tiles[s..e].iter().map(|t| t.macs).sum();
                let bytes: u64 = g.tiles[s..e].iter().map(|t| t.out_bytes).sum();
                assert_eq!(macs, net.layers[k].macs(), "layer {k} MACs");
                assert_eq!(bytes, net.layers[k].output_bytes(), "layer {k} bytes");
            }
        }
    }
}

#[test]
fn prop_auto_mode_is_thread_invariant() {
    // `exec_mode = auto` doubles the DP's candidate set; the parallel
    // engine must still reproduce the serial schedule bit-for-bit.
    for name in ["alexnet", "resnet18"] {
        let net = scope::model::zoo::by_name(name).unwrap();
        let mcm = McmConfig::paper_default(16);
        let base = SimOptions {
            samples: 8,
            exec_mode: ExecModeChoice::Auto,
            ..Default::default()
        };
        let sim1 = SimOptions { threads: 1, ..base.clone() };
        let serial = scope::scope::schedule_scope(&net, &mcm, &sim1);
        assert!(serial.eval.is_valid(), "{name}: {:?}", serial.eval.error);
        for threads in [2usize, 8] {
            let simt = SimOptions { threads, ..base.clone() };
            let par = scope::scope::schedule_scope(&net, &mcm, &simt);
            assert_eq!(
                serial.eval.total_cycles.to_bits(),
                par.eval.total_cycles.to_bits(),
                "{name}: auto drifted at {threads} threads"
            );
            assert_eq!(serial.schedule, par.schedule, "{name} @ {threads} threads");
        }
    }
}

#[test]
fn prop_mesh_cut_width_symmetric_and_bounded() {
    let mut rng = Rng::new(10);
    for _ in 0..CASES {
        let mesh = Mesh::for_chiplets(*[16usize, 32, 64].get(rng.usize_in(0, 3)).unwrap());
        let total = mesh.chiplets();
        let a0 = rng.usize_in(0, total - 1);
        let an = rng.usize_in(1, total - a0);
        let rest = total - (a0 + an);
        if rest == 0 {
            continue;
        }
        let b0 = a0 + an;
        let bn = rng.usize_in(1, rest + 1);
        let ab = mesh.cut_width(a0, an, b0, bn);
        let ba = mesh.cut_width(b0, bn, a0, an);
        assert_eq!(ab, ba, "cut width must be symmetric");
        // zigzag-contiguous adjacent ranges always touch
        assert!(ab >= 1, "adjacent zigzag ranges share ≥1 link");
        assert!(ab <= 2 * (mesh.width + mesh.height), "cut bounded by perimeter");
    }
}
