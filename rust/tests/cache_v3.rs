//! Cache-file v3 acceptance: the CLI writes the packed binary format on
//! exit, and a warm-from-binary run re-schedules zero spans — cluster
//! caches included — while reporting bit-identical results.

use std::process::Command;

fn run_cli(args: &[&str]) -> String {
    let out = Command::new(env!("CARGO_BIN_EXE_scope"))
        .args(args)
        .output()
        .expect("scope binary runs");
    assert!(
        out.status.success(),
        "scope {:?} failed: {}",
        args,
        String::from_utf8_lossy(&out.stderr)
    );
    String::from_utf8(out.stdout).expect("utf8 stdout")
}

/// Misses of the shared cluster cache in the `cache store:` totals line.
fn cluster_misses(out: &str) -> u64 {
    let line = out
        .lines()
        .find(|l| l.contains("shared cluster cache:"))
        .unwrap_or_else(|| panic!("no store totals line in: {out}"));
    let tail = line.split("shared cluster cache:").nth(1).unwrap();
    let misses = tail.split('/').nth(1).unwrap(); // " M misses"
    misses.trim().split(' ').next().unwrap().parse().expect("miss count")
}

#[test]
fn warm_from_binary_cli_reschedules_zero_spans_and_clusters() {
    let path = std::env::temp_dir()
        .join(format!("scope-cache-v3-cli-{}.bin", std::process::id()));
    let _ = std::fs::remove_file(&path);
    let p = path.to_str().unwrap();
    // `multi` exercises the whole stack: many (model, share) sweeps, the
    // shared cluster caches, and the store-backed span memos.
    let args = [
        "multi",
        "--models",
        "scopenet,scopenet:2",
        "--chiplets",
        "8",
        "--quantum",
        "4",
        "--samples",
        "4",
        "--cache-file",
        p,
    ];
    let cold = run_cli(&args);
    let bytes = std::fs::read(&path).expect("cache file written on exit");
    assert_eq!(&bytes[..8], b"SCOPECH3", "cache files persist as v3 packed binary");
    assert!(cluster_misses(&cold) > 0, "the cold run must cost clusters: {cold}");
    let warm = run_cli(&args);
    assert_eq!(
        cluster_misses(&warm),
        0,
        "a warm-from-binary run must re-cost zero clusters: {warm}"
    );
    // the co-schedule outcome itself is identical — only cache counters
    // (the store totals line) may differ between the runs
    let strip = |s: &str| -> String {
        s.lines().filter(|l| !l.contains("cache store:")).collect::<Vec<_>>().join("\n")
    };
    assert_eq!(strip(&cold), strip(&warm), "warm results must be bit-identical");
    let _ = std::fs::remove_file(&path);
}

#[test]
fn store_keys_fingerprint_class_maps_and_link_scales() {
    use scope::arch::{apply_hetero, McmConfig};
    use scope::config::SimOptions;
    use scope::model::zoo;
    use scope::pipeline::cache_store::StoreKey;

    let net = zoo::by_name("scopenet").unwrap();
    let sim = SimOptions::default();
    let uni = StoreKey::new(&net, &McmConfig::paper_default(8), "scope", &sim);

    let mut mixed = McmConfig::paper_default(8);
    apply_hetero(&mut mixed, "big4little4").unwrap();
    assert_ne!(uni, StoreKey::new(&net, &mixed, "scope", &sim), "class map must key");

    let mut swapped = McmConfig::paper_default(8);
    apply_hetero(&mut swapped, "little4big4").unwrap();
    assert_ne!(
        StoreKey::new(&net, &mixed, "scope", &sim),
        StoreKey::new(&net, &swapped, "scope", &sim),
        "slot order matters: big4little4 and little4big4 are different packages"
    );

    let mut slow = McmConfig::paper_default(8);
    apply_hetero(&mut slow, "big8/xcol0=0.5").unwrap();
    assert_ne!(uni, StoreKey::new(&net, &slow, "scope", &sim), "link scales must key");
}

#[test]
fn warm_uniform_cache_misses_on_hetero_packages() {
    let path = std::env::temp_dir()
        .join(format!("scope-cache-v3-hetero-{}.bin", std::process::id()));
    let _ = std::fs::remove_file(&path);
    let p = path.to_str().unwrap();
    let base = [
        "multi",
        "--models",
        "scopenet,scopenet:2",
        "--chiplets",
        "8",
        "--quantum",
        "4",
        "--samples",
        "4",
        "--cache-file",
        p,
    ];
    let cold = run_cli(&base);
    assert!(cluster_misses(&cold) > 0, "cold uniform run must cost clusters: {cold}");
    // a mixed-package run against the warmed uniform cache must not reuse
    // any of it — the class map is part of every store key
    let mut hetero = base.to_vec();
    hetero.extend_from_slice(&["--hetero", "big4little4"]);
    let h = run_cli(&hetero);
    assert!(cluster_misses(&h) > 0, "hetero run must re-cost its clusters: {h}");
    let _ = std::fs::remove_file(&path);
}
