//! Integration tests across the scheduling stack: the four methods, the
//! segmenter, the DSE, and the cost model, exercised together on real
//! zoo networks — the paper's qualitative claims as assertions.

use scope::arch::McmConfig;
use scope::baselines::{
    run_all, schedule_full_pipeline, schedule_segmented, schedule_sequential,
};
use scope::config::SimOptions;
use scope::model::zoo;
use scope::scope::schedule_scope;

fn opts() -> SimOptions {
    SimOptions::default()
}

#[test]
fn scope_is_never_worse_than_segmented() {
    // Scope's search space contains the segmented pipeline's; its storage
    // policy strictly relaxes capacity. Across a grid of settings Scope
    // must match or beat the SOTA baseline (paper Fig. 7: "Scope
    // consistently achieves optimal performance across all configurations").
    for (net_name, chiplets) in [
        ("alexnet", 16),
        ("alexnet", 64),
        ("darknet19", 64),
        ("resnet18", 16),
        ("resnet34", 64),
        ("resnet50", 64),
    ] {
        let net = zoo::by_name(net_name).unwrap();
        let mcm = McmConfig::paper_default(chiplets);
        let scope_r = schedule_scope(&net, &mcm, &opts());
        let seg_r = schedule_segmented(&net, &mcm, &opts());
        assert!(scope_r.eval.is_valid(), "{net_name}@{chiplets}: {:?}", scope_r.eval.error);
        if seg_r.eval.is_valid() {
            assert!(
                scope_r.throughput() >= seg_r.throughput() * 0.999,
                "{net_name}@{chiplets}: scope {} < segmented {}",
                scope_r.throughput(),
                seg_r.throughput()
            );
        }
    }
}

#[test]
fn sequential_wins_or_ties_small_scale_loses_at_large_scale() {
    // Paper: "Sequential execution exhibits better performance with fewer
    // chiplets ... as the hardware scales, its performance significantly
    // degrades and becomes the least efficient scheduling."
    let net = zoo::resnet50();
    let seq_256 = schedule_sequential(&net, &McmConfig::paper_default(256), &opts());
    let scope_256 = schedule_scope(&net, &McmConfig::paper_default(256), &opts());
    assert!(
        scope_256.throughput() > seq_256.throughput() * 2.0,
        "at 256 chiplets scope must dominate sequential ({} vs {})",
        scope_256.throughput(),
        seq_256.throughput()
    );
    // and the sequential/scope ratio must shrink with scale
    let seq_16 = schedule_sequential(&net, &McmConfig::paper_default(16), &opts());
    let scope_16 = schedule_scope(&net, &McmConfig::paper_default(16), &opts());
    let ratio_16 = seq_16.throughput() / scope_16.throughput();
    let ratio_256 = seq_256.throughput() / scope_256.throughput();
    assert!(
        ratio_256 < ratio_16,
        "sequential's relative standing must degrade with scale ({ratio_16} → {ratio_256})"
    );
}

#[test]
fn full_pipeline_invalid_on_deep_nets_valid_on_shallow() {
    // Paper Fig. 7: full pipelining "even fail[s] to be valid due to
    // weight buffer overflow" on deep networks.
    let deep = schedule_full_pipeline(
        &zoo::resnet152(),
        &McmConfig::paper_default(64),
        &opts(),
    );
    assert!(!deep.eval.is_valid());
    let shallow = schedule_full_pipeline(
        &zoo::scopenet(),
        &McmConfig::paper_default(16),
        &opts(),
    );
    assert!(shallow.eval.is_valid(), "{:?}", shallow.eval.error);
}

#[test]
fn scope_throughput_scales_with_chiplets() {
    // Paper Fig. 9: Scope exhibits the best scalability. Monotone
    // improvement across the scale sweep.
    let net = zoo::darknet19();
    let mut last = 0.0;
    for chiplets in [16, 32, 64, 128] {
        let r = schedule_scope(&net, &McmConfig::paper_default(chiplets), &opts());
        assert!(r.eval.is_valid(), "@{chiplets}: {:?}", r.eval.error);
        assert!(
            r.throughput() > last,
            "throughput must grow 16→128: {} then {}",
            last,
            r.throughput()
        );
        last = r.throughput();
    }
}

#[test]
fn scope_uses_fewer_or_equal_segments_than_segmented() {
    // Paper Fig. 10 narrative: merging lets Scope cover the net in fewer
    // segments (2 vs 3 on resnet152@256).
    let net = zoo::resnet50();
    let mcm = McmConfig::paper_default(64);
    let scope_r = schedule_scope(&net, &mcm, &opts());
    let seg_r = schedule_segmented(&net, &mcm, &opts());
    let s_scope = scope_r.schedule.as_ref().unwrap().segments.len();
    let s_seg = seg_r.schedule.as_ref().unwrap().segments.len();
    assert!(s_scope <= s_seg, "scope {s_scope} segments > segmented {s_seg}");
}

#[test]
fn schedules_respect_package_limits() {
    for (net_name, chiplets) in [("alexnet", 16), ("resnet50", 64), ("vgg16", 256)] {
        let net = zoo::by_name(net_name).unwrap();
        let mcm = McmConfig::paper_default(chiplets);
        for r in run_all(&net, &mcm, &opts()) {
            if let Some(sched) = &r.schedule {
                sched
                    .validate(&net, chiplets)
                    .unwrap_or_else(|e| panic!("{net_name}@{chiplets} {}: {e}", r.method));
                for seg in &sched.segments {
                    assert!(seg.regions.iter().sum::<usize>() <= chiplets);
                }
            }
        }
    }
}

#[test]
fn energy_comparable_latency_better() {
    // Paper Fig. 10b: Scope and segmented have "roughly equivalent energy
    // consumption and breakdown"; the win is throughput. Allow ±30%.
    let net = zoo::resnet50();
    let mcm = McmConfig::paper_default(256);
    let scope_r = schedule_scope(&net, &mcm, &opts());
    let seg_r = schedule_segmented(&net, &mcm, &opts());
    assert!(scope_r.eval.is_valid() && seg_r.eval.is_valid());
    let e_ratio = scope_r.eval.energy.total_pj() / seg_r.eval.energy.total_pj();
    assert!(
        (0.7..1.3).contains(&e_ratio),
        "energy should be comparable, ratio = {e_ratio}"
    );
    assert!(scope_r.throughput() >= seg_r.throughput() * 0.999);
}

#[test]
fn overlap_and_distribution_never_hurt() {
    let net = zoo::darknet19();
    let mcm = McmConfig::paper_default(64);
    let on = schedule_scope(&net, &mcm, &opts());
    let no_overlap = SimOptions { overlap_comm: false, ..opts() };
    let off = schedule_scope(&net, &mcm, &no_overlap);
    assert!(on.throughput() >= off.throughput() * 0.999, "overlap must help or tie");
}
