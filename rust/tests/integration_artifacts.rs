//! Integration tests over the AOT artifact path: manifest ↔ model-zoo
//! consistency, PJRT execution, and the functional coordinator.
//!
//! All tests no-op gracefully when `artifacts/` has not been built
//! (CI-of-the-poor: `make artifacts` is a build step, not a test step).

use scope::coordinator::{run_pipeline, PipelineMode};
use scope::model::zoo::{scopenet, SCOPENET_CLUSTERS};
use scope::runtime::{Manifest, Runtime};

fn manifest() -> Option<Manifest> {
    let dir = Manifest::default_dir();
    if dir.join("manifest.json").exists() {
        Some(Manifest::load(&dir).expect("manifest must parse"))
    } else {
        eprintln!("skipping: artifacts not built (run `make artifacts`)");
        None
    }
}

#[test]
fn manifest_matches_rust_zoo_model() {
    // The rust scopenet() chain and the python ScopeNet that produced the
    // artifacts must agree on every cluster boundary's activation shape.
    let Some(m) = manifest() else { return };
    let net = scopenet();
    assert_eq!(m.clusters.len(), SCOPENET_CLUSTERS.len());
    assert_eq!(
        m.input_shape,
        vec![net.input.0 as usize, net.input.1 as usize, net.input.2 as usize]
    );
    for (c, &(lo, hi)) in m.clusters.iter().zip(SCOPENET_CLUSTERS) {
        let _ = lo;
        let (h, w, ch) = net.layers[hi - 1].out_shape();
        let want: Vec<usize> = if c.output_shape.len() == 1 {
            vec![(h * w * ch) as usize]
        } else {
            vec![h as usize, w as usize, ch as usize]
        };
        assert_eq!(c.output_shape, want, "cluster {} output", c.index);
    }
}

#[test]
fn cluster_chain_equals_full_module() {
    // Execute the three cluster modules in sequence and the monolithic
    // module; outputs must agree bitwise-ish (same kernels, same order).
    let Some(m) = manifest() else { return };
    let rt = Runtime::cpu().unwrap();
    let (xs, _) = m.golden().unwrap();
    let mut act = xs[0].clone();
    for c in &m.clusters {
        let mut shapes = vec![c.input_shape.clone()];
        shapes.extend(c.param_shapes.iter().cloned());
        let exe = rt.load_hlo(&c.file, &shapes).unwrap();
        let params = Manifest::load_params(&c.params_file, &c.param_shapes).unwrap();
        let mut inputs: Vec<(&[f32], &[usize])> = vec![(&act, &c.input_shape[..])];
        for (p, s) in params.iter().zip(&c.param_shapes) {
            inputs.push((p, s));
        }
        act = exe.run(&inputs).unwrap();
    }
    let mut shapes = vec![m.input_shape.clone()];
    shapes.extend(m.full_param_shapes.iter().cloned());
    let full = rt.load_hlo(&m.full_file, &shapes).unwrap();
    let params = Manifest::load_params(&m.full_params_file, &m.full_param_shapes).unwrap();
    let mut inputs: Vec<(&[f32], &[usize])> = vec![(&xs[0], &m.input_shape[..])];
    for (p, s) in params.iter().zip(&m.full_param_shapes) {
        inputs.push((p, s));
    }
    let want = full.run(&inputs).unwrap();
    assert_eq!(act.len(), want.len());
    for (a, b) in act.iter().zip(&want) {
        assert!((a - b).abs() < 1e-4, "{a} vs {b}");
    }
}

#[test]
fn all_pipeline_modes_agree_with_golden() {
    let Some(m) = manifest() else { return };
    for mode in [PipelineMode::Single, PipelineMode::Merged, PipelineMode::MergedIsp] {
        let r = run_pipeline(&m, mode, 5).unwrap();
        assert!(
            r.numerics_ok(1e-3),
            "{}: max_abs_err {}",
            r.mode,
            r.max_abs_err
        );
        assert_eq!(r.samples, 5);
        assert_eq!(r.latencies.len(), 5);
        assert!(r.wall_secs > 0.0);
    }
}

#[test]
fn pipeline_handles_more_samples_than_golden_batch() {
    // samples cycle through the golden inputs; 11 > 4 exercises the wrap.
    let Some(m) = manifest() else { return };
    let r = run_pipeline(&m, PipelineMode::Merged, 11).unwrap();
    assert!(r.numerics_ok(1e-3));
    assert_eq!(r.samples, 11);
}

#[test]
fn isp_shard_modules_gather_to_cluster_output() {
    // Run cluster1 monolithically and via the ISP shard modules + channel
    // gather; both paths must produce the same activation.
    let Some(m) = manifest() else { return };
    let rt = Runtime::cpu().unwrap();
    let (xs, _) = m.golden().unwrap();

    // input to cluster 1 = output of cluster 0
    let c0 = &m.clusters[0];
    let mut shapes = vec![c0.input_shape.clone()];
    shapes.extend(c0.param_shapes.iter().cloned());
    let exe0 = rt.load_hlo(&c0.file, &shapes).unwrap();
    let p0 = Manifest::load_params(&c0.params_file, &c0.param_shapes).unwrap();
    let mut inputs: Vec<(&[f32], &[usize])> = vec![(&xs[0], &c0.input_shape[..])];
    for (p, s) in p0.iter().zip(&c0.param_shapes) {
        inputs.push((p, s));
    }
    let act1 = exe0.run(&inputs).unwrap();

    // monolithic cluster 1
    let c1 = &m.clusters[m.isp_cluster];
    let mut shapes = vec![c1.input_shape.clone()];
    shapes.extend(c1.param_shapes.iter().cloned());
    let exe1 = rt.load_hlo(&c1.file, &shapes).unwrap();
    let p1 = Manifest::load_params(&c1.params_file, &c1.param_shapes).unwrap();
    let mut inputs: Vec<(&[f32], &[usize])> = vec![(&act1, &c1.input_shape[..])];
    for (p, s) in p1.iter().zip(&c1.param_shapes) {
        inputs.push((p, s));
    }
    let want = exe1.run(&inputs).unwrap();

    // sharded path
    let mut act = act1;
    for e in &m.isp_layers {
        let mut halves = Vec::new();
        for (file, (pfile, pshapes)) in e.files.iter().zip(&e.shard_params) {
            let mut shapes = vec![e.input_shape.clone()];
            shapes.extend(pshapes.iter().cloned());
            let exe = rt.load_hlo(file, &shapes).unwrap();
            let params = Manifest::load_params(pfile, pshapes).unwrap();
            let mut inputs: Vec<(&[f32], &[usize])> = vec![(&act, &e.input_shape[..])];
            for (p, s) in params.iter().zip(pshapes) {
                inputs.push((p, s));
            }
            halves.push(exe.run(&inputs).unwrap());
        }
        act = scope::coordinator::worker::gather_channels(&halves, &e.shard_output_shape);
    }
    assert_eq!(act.len(), want.len());
    for (a, b) in act.iter().zip(&want) {
        assert!((a - b).abs() < 1e-4, "{a} vs {b}");
    }
}
