//! Allocation-count regression tests for the DSE hot path.
//!
//! The arena change (PR 7) made [`scope::pipeline::eval_cache::ClusterKey`]
//! `Copy` (partitions packed into a [`scope::pipeline::PartBits`]) and the
//! span memo's hit path clone-free for `Copy` payloads. These tests pin
//! that property with a counting global allocator: the micro checks assert
//! literally zero heap allocations on the per-candidate paths, and the
//! end-to-end check asserts a warm segment DP over resnet152 allocates
//! less than once per candidate span it looks up.
//!
//! Everything lives in ONE `#[test]` — the counter is process-global, and
//! concurrent tests would bleed into each other's measurements.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};

use scope::arch::McmConfig;
use scope::config::SimOptions;
use scope::model::zoo;
use scope::pipeline::cache_store::StoreKey;
use scope::pipeline::eval_cache::ClusterKey;
use scope::pipeline::schedule::{ExecMode, Partition, SegmentSchedule};
use scope::scope::segment_dp::SpanMemo;
use scope::scope::{search_segments_dag, SegmenterKind, SegmenterOptions};
use scope::util::fxhash::FxHashMap;

struct CountingAlloc;

static ALLOCS: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        unsafe { System.alloc(layout) }
    }
    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        unsafe { System.dealloc(ptr, layout) }
    }
    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        unsafe { System.realloc(ptr, layout, new_size) }
    }
}

#[global_allocator]
static COUNTER: CountingAlloc = CountingAlloc;

fn allocs() -> u64 {
    ALLOCS.load(Ordering::SeqCst)
}

/// A 100-layer segment whose partition pattern crosses the 64-bit word
/// boundary of the packed key.
fn wide_segment() -> SegmentSchedule {
    SegmentSchedule {
        lo: 0,
        hi: 100,
        bounds: vec![0, 30, 70, 100],
        regions: vec![8, 8, 8],
        partitions: (0..100)
            .map(|i| if i % 3 == 0 { Partition::Isp } else { Partition::Wsp })
            .collect(),
        exec_mode: ExecMode::Pipeline,
    }
}

#[test]
fn hot_paths_do_not_allocate_per_candidate_span() {
    // --- micro: span-memo hits with a Copy payload are allocation-free
    let mut memo: SpanMemo<(usize, usize)> = SpanMemo::new();
    let mut eval = |lo: usize, hi: usize| Some(((lo, hi), (hi - lo) as f64));
    for lo in 0..64usize {
        memo.get_or_eval(lo, lo + 1, &mut eval);
    }
    let before = allocs();
    for _ in 0..1_000 {
        for lo in 0..64usize {
            std::hint::black_box(memo.get_or_eval(lo, lo + 1, &mut eval));
        }
    }
    assert_eq!(allocs() - before, 0, "span-memo hits must not touch the heap");

    // --- micro: building, hashing, and looking up a ClusterKey is
    // allocation-free (the former Vec<Partition> key allocated every time)
    let seg = wide_segment();
    let mut table: FxHashMap<ClusterKey, u64> = FxHashMap::default();
    for j in 0..3usize {
        table.insert(ClusterKey::of(&seg, j), j as u64);
    }
    let before = allocs();
    let mut acc = 0u64;
    for _ in 0..1_000 {
        for j in 0..3usize {
            let key = ClusterKey::of(&seg, j);
            acc = acc.wrapping_add(*table.get(&key).expect("populated"));
        }
    }
    std::hint::black_box(acc);
    assert_eq!(allocs() - before, 0, "ClusterKey::of + lookup must not touch the heap");

    // --- end-to-end: segment DP on resnet152, cold then warm under a
    // process-store key. The warm pass answers every candidate span from
    // the memo; after the arena change it must allocate less than once
    // per span it serves (the residue is the DP's own per-count tables,
    // not per-candidate traffic).
    let net = zoo::by_name("resnet152").expect("zoo net");
    let mcm = McmConfig::paper_default(64);
    let store_key = StoreKey::new(&net, &mcm, "alloc-count-test", &SimOptions::default());
    let provider = |lo: usize, hi: usize| {
        // cheap pure stand-in span cost with a Copy schedule: this test
        // measures the DP machinery, not the scheduler
        Some(((lo, hi), (hi - lo) as f64 + lo as f64 * 1e-3))
    };
    let opts = || SegmenterOptions {
        kind: SegmenterKind::Dp,
        dp_window: 4,
        dp_window_auto: false,
        store: Some(store_key),
        prune: false,
    };
    let cold = search_segments_dag(&net, &mcm, 8, 1, 16, usize::MAX, 1, opts(), &provider)
        .expect("resnet152 segments");
    let cold_misses = cold.stats.misses;
    assert!(cold_misses > 200, "expected a real span population, got {cold_misses}");
    let before = allocs();
    let warm = search_segments_dag(&net, &mcm, 8, 1, 16, usize::MAX, 1, opts(), &provider)
        .expect("resnet152 segments");
    let warm_allocs = allocs() - before;
    assert_eq!(warm.stats.misses, 0, "warm sweep must be served entirely by the memo");
    assert_eq!(
        warm.total_latency.to_bits(),
        cold.total_latency.to_bits(),
        "memo reuse must not change the result"
    );
    assert_eq!(warm.bounds, cold.bounds);
    assert!(
        warm_allocs < cold_misses as u64,
        "warm DP allocated {warm_allocs}x for {cold_misses} candidate spans — \
         the hit path must stay heap-free"
    );
}
