//! Serving-simulator acceptance tests: CLI and library determinism
//! (bit-identical reports across repeat runs and thread counts), the
//! hybrid-beats-spatial SLO case, allocator pruning properties, named
//! input-validation errors, and warm-from-disk cache-file reuse.

use std::process::Command;

use scope::arch::McmConfig;
use scope::config::SimOptions;
use scope::model::WorkloadSet;
use scope::serve::trace::RequestStream;
use scope::serve::{serve, ServeOptions};

fn run_cli(args: &[&str]) -> String {
    let out = Command::new(env!("CARGO_BIN_EXE_scope"))
        .args(args)
        .output()
        .expect("scope binary runs");
    assert!(
        out.status.success(),
        "scope {:?} failed: {}",
        args,
        String::from_utf8_lossy(&out.stderr)
    );
    String::from_utf8(out.stdout).expect("utf8 stdout")
}

fn run_cli_expect_err(args: &[&str], needle: &str) {
    let out = Command::new(env!("CARGO_BIN_EXE_scope"))
        .args(args)
        .output()
        .expect("scope binary runs");
    assert!(!out.status.success(), "scope {args:?} should have failed");
    let err = String::from_utf8_lossy(&out.stderr);
    assert!(err.contains(needle), "scope {args:?}: expected {needle:?} in: {err}");
}

/// The acceptance-criteria invocation (`--models serving_mix --seed 7`),
/// with small knobs so the scheduling stays test-sized.
const SERVE_ARGS: &[&str] = &[
    "serve",
    "--models",
    "serving_mix",
    "--seed",
    "7",
    "--chiplets",
    "16",
    "--quantum",
    "8",
    "--samples",
    "4",
    "--batch",
    "2",
    "--arrival-rate",
    "40",
    "--horizon",
    "0.05",
];

#[test]
fn cli_serve_is_bit_identical_across_runs_and_threads() {
    let base = run_cli(SERVE_ARGS);
    assert!(base.contains("serving simulation"), "{base}");
    assert!(base.contains("completed:"), "{base}");
    assert!(base.contains("hybrid"), "{base}");
    let again = run_cli(SERVE_ARGS);
    assert_eq!(base, again, "two consecutive process runs must match bit for bit");
    for threads in ["1", "2", "8"] {
        let mut args = SERVE_ARGS.to_vec();
        args.extend(["--threads", threads]);
        let got = run_cli(&args);
        assert_eq!(base, got, "--threads {threads} drifted from the default run");
    }
}

#[test]
fn library_serve_outcomes_and_logs_are_thread_invariant() {
    let mut set = WorkloadSet::parse("alexnet,scopenet:2").unwrap();
    set.apply_slo_spec("20000").unwrap();
    let mcm = McmConfig::paper_default(16);
    let sopts = ServeOptions {
        arrival_rate: 60.0,
        horizon_secs: 0.03,
        max_batch: 2,
        share_quantum: 8,
        seed: 11,
        ..ServeOptions::default()
    };
    let stream = RequestStream::poisson(&set, sopts.arrival_rate, sopts.horizon_ns(), sopts.seed);
    let run = |threads: usize| {
        let sim = SimOptions {
            samples: 4,
            threads,
            cache_store: true,
            ..SimOptions::default()
        };
        serve(&set, &mcm, &sim, &sopts, &stream)
    };
    let base = run(1);
    assert!(base.is_valid(), "{:?}", base.error);
    let base_hybrid = base.hybrid.clone().expect("a winner exists");
    for threads in [2usize, 8] {
        let got = run(threads);
        assert_eq!(got.hybrid, Some(base_hybrid.clone()), "threads={threads}");
        assert_eq!(got.spatial, base.spatial, "threads={threads}");
        assert_eq!(got.tm, base.tm, "threads={threads}");
        assert_eq!(got.allocations, base.allocations, "threads={threads}");
        assert_eq!(got.feasible_allocations, base.feasible_allocations);
    }
    // the full event log replays bit-identically on a plain repeat
    let again = run(1);
    assert_eq!(again.hybrid.unwrap().sim.log, base_hybrid.sim.log);
}

#[test]
fn hybrid_temporal_share_meets_an_slo_pure_spatial_violates() {
    // vgg16 cannot schedule on an 8-chiplet share: its ~138 MB of weights
    // need more segments than it has layers under 8 MiB of package weight
    // buffer (min_segments = 17 > 16 layers). The only pure-spatial
    // allocation of a 16-chiplet package at quantum 8 is (8, 8), so every
    // spatial allocation is infeasible and blows the SLO — while
    // time-multiplexing both models on the full 16-chiplet package serves
    // every request orders of magnitude inside a generous bound. Hybrid
    // thus meets an SLO the pure spatial allocator violates at the same
    // arrival rate.
    let mut set = WorkloadSet::parse("vgg16,scopenet").unwrap();
    set.apply_slo_spec("10000").unwrap(); // 10 s
    let mcm = McmConfig::paper_default(16);
    let sim = SimOptions { samples: 4, cache_store: true, ..SimOptions::default() };
    let sopts = ServeOptions {
        arrival_rate: 4.0,
        horizon_secs: 0.5,
        max_batch: 2,
        share_quantum: 8,
        seed: 7,
        ..ServeOptions::default()
    };
    let stream = RequestStream::poisson(&set, sopts.arrival_rate, sopts.horizon_ns(), sopts.seed);
    assert!(!stream.is_empty(), "seed 7 must generate arrivals");
    let r = serve(&set, &mcm, &sim, &sopts, &stream);
    assert!(r.is_valid(), "{:?}", r.error);
    let spatial = r.spatial.as_ref().expect("the (8, 8) split exists on the grid");
    assert!(!spatial.sim.feasible, "vgg16@8 must be unschedulable by capacity");
    assert!(!spatial.meets_all_slos, "an unservable model violates its SLO");
    let hybrid = r.hybrid.as_ref().expect("a winner exists");
    assert!(
        hybrid.meets_all_slos,
        "hybrid must meet the SLO the spatial split violates (worst ratio {})",
        hybrid.worst_slo_ratio
    );
    assert!(
        hybrid.alloc.groups.iter().any(|g| g.members.len() >= 2),
        "the winner must time-multiplex: {:?}",
        hybrid.alloc
    );
    assert_eq!(hybrid.sim.completed as usize, stream.len(), "every request served");
    for stats in &hybrid.sim.per_model {
        assert!(stats.meets_slo());
        assert!(stats.p99_ns <= stats.slo_ns.unwrap());
    }
    assert!(r.slo_feasible_allocations > 0);
    assert!(hybrid.sim.swaps > 0, "temporal sharing pays real weight swaps");
}

#[test]
fn hybrid_allocator_prunes_slo_violators_across_seeds() {
    let base_set = WorkloadSet::parse("alexnet,scopenet").unwrap();
    let mcm = McmConfig::paper_default(16);
    let sim = SimOptions { samples: 4, cache_store: true, ..SimOptions::default() };
    for seed in [1u64, 2, 3] {
        let sopts = ServeOptions {
            arrival_rate: 200.0,
            horizon_secs: 0.05,
            max_batch: 2,
            share_quantum: 8,
            seed,
            ..ServeOptions::default()
        };
        let stream =
            RequestStream::poisson(&base_set, sopts.arrival_rate, sopts.horizon_ns(), sopts.seed);
        assert!(!stream.is_empty(), "seed {seed}");
        // generous bound: satisfiable, and the winner honors it
        let mut set = base_set.clone();
        set.apply_slo_spec("60000").unwrap();
        let r = serve(&set, &mcm, &sim, &sopts, &stream);
        assert!(r.is_valid(), "seed {seed}: {:?}", r.error);
        assert!(r.slo_feasible_allocations > 0, "seed {seed}: bound must be satisfiable");
        let hybrid = r.hybrid.as_ref().unwrap();
        assert!(hybrid.meets_all_slos, "seed {seed}");
        for stats in &hybrid.sim.per_model {
            assert!(
                stats.p99_ns <= stats.slo_ns.unwrap(),
                "seed {seed}: allocator returned a p99 above a declared SLO"
            );
        }
        // absurdly tight bound: nothing can meet it, and the allocator
        // reports that instead of claiming success
        let mut tight = base_set.clone();
        tight.apply_slo_spec("0.000001").unwrap();
        let rt = serve(&tight, &mcm, &sim, &sopts, &stream);
        assert!(rt.is_valid(), "seed {seed}");
        assert_eq!(rt.slo_feasible_allocations, 0, "seed {seed}");
        assert!(!rt.hybrid.as_ref().unwrap().meets_all_slos, "seed {seed}");
    }
}

#[test]
fn cli_rejects_bad_serving_inputs_by_name() {
    // unknown --models entry names the offender (multi and serve surface)
    run_cli_expect_err(&["serve", "--models", "nosuchnet", "--chiplets", "8"], "nosuchnet");
    run_cli_expect_err(&["multi", "--models", "nosuchnet", "--chiplets", "8"], "nosuchnet");
    // zero / negative model weights name the model
    run_cli_expect_err(&["serve", "--models", "alexnet:0", "--chiplets", "8"], "alexnet");
    run_cli_expect_err(&["multi", "--models", "alexnet:-1", "--chiplets", "8"], "alexnet");
    // --quantum 0 is rejected by flag name on both subcommands
    run_cli_expect_err(
        &["serve", "--models", "alexnet", "--quantum", "0", "--chiplets", "8"],
        "--quantum",
    );
    run_cli_expect_err(
        &["multi", "--models", "alexnet", "--quantum", "0", "--chiplets", "8"],
        "--quantum",
    );
    // serve stream/SLO knobs are validated up front, naming the flag
    run_cli_expect_err(
        &["serve", "--models", "alexnet", "--slo", "nosuchnet:5", "--chiplets", "8"],
        "nosuchnet",
    );
    run_cli_expect_err(
        &["serve", "--models", "alexnet", "--arrival-rate", "0", "--chiplets", "8"],
        "--arrival-rate",
    );
    run_cli_expect_err(
        &["serve", "--models", "alexnet", "--batch", "0", "--chiplets", "8"],
        "--batch",
    );
    run_cli_expect_err(
        &["serve", "--models", "alexnet", "--horizon", "-1", "--chiplets", "8"],
        "--horizon",
    );
    // a fat-fingered rate errors by name instead of OOMing on generation
    run_cli_expect_err(
        &["serve", "--models", "alexnet", "--arrival-rate", "1e12", "--chiplets", "8"],
        "--arrival-rate",
    );
}

#[test]
fn warm_cache_file_reschedules_zero_spans() {
    let path = std::env::temp_dir()
        .join(format!("scope-warm-cache-{}.json", std::process::id()));
    let _ = std::fs::remove_file(&path);
    let p = path.to_str().unwrap();
    let args = [
        "search",
        "--net",
        "alexnet",
        "--chiplets",
        "16",
        "--segmenter",
        "dp",
        "--samples",
        "8",
        "--cache-file",
        p,
    ];
    let cold = run_cli(&args);
    assert!(path.exists(), "cache file must be written on exit");
    assert!(
        !cold.contains("/ 0 misses"),
        "the cold run must schedule spans: {cold}"
    );
    let warm = run_cli(&args);
    assert!(
        warm.contains("/ 0 misses"),
        "a warm-from-disk run must re-schedule zero spans: {warm}"
    );
    // the scheduling outcome itself is identical — only cache counters move
    let strip = |s: &str| -> String {
        s.lines().filter(|l| !l.contains("span cache")).collect::<Vec<_>>().join("\n")
    };
    assert_eq!(strip(&cold), strip(&warm), "warm results must be bit-identical");
    let _ = std::fs::remove_file(&path);
}
