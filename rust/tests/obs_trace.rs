//! Observability acceptance tests: `--trace-out` / `--metrics-out` leave
//! every subcommand's results bit-identical (tracing on/off, repeat runs,
//! `--threads 1/2/8`), the emitted Chrome trace-event JSON carries the
//! schema fields Perfetto needs and is time-ordered per track, and the
//! metrics document is byte-stable with the documented schema tag.

use std::path::PathBuf;
use std::process::Command;

use scope::util::json::Json;

fn run_cli(args: &[&str]) -> String {
    let out = Command::new(env!("CARGO_BIN_EXE_scope"))
        .args(args)
        .output()
        .expect("scope binary runs");
    assert!(
        out.status.success(),
        "scope {:?} failed: {}",
        args,
        String::from_utf8_lossy(&out.stderr)
    );
    String::from_utf8(out.stdout).expect("utf8 stdout")
}

/// Unique temp path per (process, label) so parallel tests never collide.
fn tmp(label: &str) -> PathBuf {
    std::env::temp_dir().join(format!("scope_obs_{}_{label}", std::process::id()))
}

/// Stdout with the observability `wrote ...` lines removed (their paths
/// differ per invocation); everything else must be unaffected by tracing.
fn strip_obs_lines(out: &str) -> String {
    out.lines()
        .filter(|l| !l.starts_with("trace: wrote") && !l.starts_with("metrics: wrote"))
        .map(|l| format!("{l}\n"))
        .collect()
}

/// Parse + schema-check a Chrome trace-event document: every event
/// carries name/ph/ts/pid/tid, `"X"` events carry `dur`, and timestamps
/// are non-decreasing per (pid, tid) track. Returns the number of
/// non-metadata events.
fn validate_chrome(text: &str) -> usize {
    let doc = Json::parse(text).expect("trace parses as JSON");
    assert_eq!(doc.get("displayTimeUnit").unwrap().as_str().unwrap(), "ms");
    let events = doc.get("traceEvents").unwrap().as_arr().expect("traceEvents array");
    let mut last_ts: std::collections::BTreeMap<(u64, u64), f64> = Default::default();
    let mut real = 0usize;
    for e in events {
        for key in ["name", "ph", "ts", "pid", "tid"] {
            assert!(e.get(key).is_ok(), "missing {key} in {e:?}");
        }
        let ph = e.get("ph").unwrap().as_str().unwrap().to_string();
        if ph == "M" {
            continue;
        }
        real += 1;
        assert!(ph == "X" || ph == "i", "unexpected phase {ph:?}");
        if ph == "X" {
            assert!(e.get("dur").is_ok(), "complete event without dur: {e:?}");
        }
        let track = (
            e.get("pid").unwrap().as_f64().unwrap() as u64,
            e.get("tid").unwrap().as_f64().unwrap() as u64,
        );
        let ts = e.get("ts").unwrap().as_f64().unwrap();
        if let Some(prev) = last_ts.insert(track, ts) {
            assert!(prev <= ts, "track {track:?} out of order: {prev} > {ts}");
        }
    }
    real
}

fn counter(doc: &Json, name: &str) -> f64 {
    doc.get("counters")
        .unwrap()
        .get(name)
        .unwrap_or_else(|_| panic!("metrics missing counter {name}"))
        .as_f64()
        .unwrap()
}

const SERVE_ARGS: &[&str] = &[
    "serve",
    "--models",
    "serving_mix",
    "--seed",
    "7",
    "--chiplets",
    "16",
    "--quantum",
    "8",
    "--samples",
    "4",
    "--batch",
    "2",
    "--arrival-rate",
    "40",
    "--horizon",
    "0.05",
];

#[test]
fn serve_trace_and_metrics_are_bit_identical_and_leave_results_unchanged() {
    let base = run_cli(SERVE_ARGS);
    assert!(base.contains("serving simulation"), "{base}");

    let mut traces: Vec<String> = Vec::new();
    let mut metrics: Vec<String> = Vec::new();
    // threads 1/2/8 plus a plain repeat of threads 1: every emitted file
    // must match byte for byte, and the report must not notice tracing
    for (i, threads) in ["1", "2", "8", "1"].iter().enumerate() {
        let t_path = tmp(&format!("serve_t{i}.json"));
        let m_path = tmp(&format!("serve_m{i}.json"));
        let (t_s, m_s) = (t_path.display().to_string(), m_path.display().to_string());
        let mut args = SERVE_ARGS.to_vec();
        args.extend(["--threads", threads, "--trace-out", &t_s, "--metrics-out", &m_s]);
        let out = run_cli(&args);
        assert!(out.contains("trace: wrote"), "{out}");
        assert!(out.contains("metrics: wrote"), "{out}");
        assert_eq!(
            strip_obs_lines(&out),
            base,
            "--threads {threads} with tracing drifted from the untraced run"
        );
        traces.push(std::fs::read_to_string(&t_path).expect("trace file"));
        metrics.push(std::fs::read_to_string(&m_path).expect("metrics file"));
        let _ = std::fs::remove_file(&t_path);
        let _ = std::fs::remove_file(&m_path);
    }
    for i in 1..traces.len() {
        assert_eq!(traces[0], traces[i], "trace file {i} differs from the first");
        assert_eq!(metrics[0], metrics[i], "metrics file {i} differs from the first");
    }

    // Chrome schema: per-share batch spans + per-model arrival instants
    let n = validate_chrome(&traces[0]);
    assert!(n > 0, "serve trace recorded no events");
    assert!(traces[0].contains("\"cat\":\"batch\""), "no batch-service spans in trace");
    assert!(traces[0].contains("\"cat\":\"arrival\""), "no arrival instants in trace");

    // metrics document: schema tag + the serving counters
    let doc = Json::parse(&metrics[0]).expect("metrics parse");
    assert_eq!(doc.get("schema").unwrap().as_str().unwrap(), "scope-metrics-v1");
    assert!(counter(&doc, "scope_serve_completed") > 0.0);
    assert!(counter(&doc, "scope_serve_evals") > 0.0);
    assert!(counter(&doc, "scope_serve_allocations") > 0.0);
}

#[test]
fn serve_metrics_prometheus_text_when_path_says_prom() {
    let m_path = tmp("serve.prom");
    let m_s = m_path.display().to_string();
    let mut args = SERVE_ARGS.to_vec();
    args.extend(["--metrics-out", &m_s]);
    run_cli(&args);
    let text = std::fs::read_to_string(&m_path).expect("prom file");
    let _ = std::fs::remove_file(&m_path);
    assert!(text.contains("# TYPE scope_serve_completed counter"), "{text}");
    assert!(text.contains("scope_serve_evals "), "{text}");
}

const SEARCH_ARGS: &[&str] = &[
    "search",
    "--net",
    "alexnet",
    "--chiplets",
    "16",
    "--samples",
    "4",
    "--segmenter",
    "dp",
];

#[test]
fn search_trace_gantt_is_stable_and_leaves_results_unchanged() {
    let base = run_cli(SEARCH_ARGS);
    assert!(base.contains("Scope schedule"), "{base}");

    let mut traces: Vec<String> = Vec::new();
    for (i, threads) in ["1", "2", "1"].iter().enumerate() {
        let t_path = tmp(&format!("search_t{i}.json"));
        let m_path = tmp(&format!("search_m{i}.json"));
        let (t_s, m_s) = (t_path.display().to_string(), m_path.display().to_string());
        let mut args = SEARCH_ARGS.to_vec();
        args.extend(["--threads", threads, "--trace-out", &t_s, "--metrics-out", &m_s]);
        let out = run_cli(&args);
        assert_eq!(strip_obs_lines(&out), base, "--threads {threads} drifted under tracing");
        traces.push(std::fs::read_to_string(&t_path).expect("trace file"));

        // the DP sweep's span-memo traffic lands in the metrics registry
        let doc = Json::parse(&std::fs::read_to_string(&m_path).expect("metrics file"))
            .expect("metrics parse");
        assert!(counter(&doc, "scope_span_memo_misses") > 0.0, "dp sweep scheduled no spans");
        assert!(doc.get("counters").unwrap().get("scope_dp_bounded_out").is_ok());
        let _ = std::fs::remove_file(&t_path);
        let _ = std::fs::remove_file(&m_path);
    }
    for i in 1..traces.len() {
        assert_eq!(traces[0], traces[i], "trace file {i} differs from the first");
    }
    let n = validate_chrome(&traces[0]);
    assert!(n > 0, "search trace recorded no events");
    assert!(traces[0].contains("\"cat\":\"compute\""), "no compute spans in the Gantt");
    assert!(traces[0].contains("cluster"), "no cluster track names in the Gantt");
}

#[test]
fn trace_level_full_adds_wall_clock_search_spans() {
    let t_path = tmp("search_full.json");
    let t_s = t_path.display().to_string();
    let mut args = SEARCH_ARGS.to_vec();
    args.extend(["--trace-out", &t_s, "--trace-level", "full"]);
    run_cli(&args);
    let text = std::fs::read_to_string(&t_path).expect("trace file");
    let _ = std::fs::remove_file(&t_path);
    validate_chrome(&text);
    // wall-clock DSE spans carry the "dse" category on the search pid
    assert!(text.contains("\"cat\":\"dse\""), "no wall-clock spans at --trace-level full");
}

#[test]
fn multi_results_unchanged_and_metrics_carry_co_schedule_counters() {
    let args: Vec<&str> = vec![
        "multi", "--models", "alexnet,scopenet:2", "--chiplets", "16", "--samples", "4",
    ];
    let base = run_cli(&args);
    assert!(base.contains("co-scheduled"), "{base}");

    let m_path = tmp("multi_m.json");
    let m_s = m_path.display().to_string();
    let mut traced = args.clone();
    traced.extend(["--metrics-out", &m_s]);
    let out = run_cli(&traced);
    assert_eq!(strip_obs_lines(&out), base, "multi drifted under --metrics-out");
    let doc = Json::parse(&std::fs::read_to_string(&m_path).expect("metrics file"))
        .expect("metrics parse");
    let _ = std::fs::remove_file(&m_path);
    assert!(counter(&doc, "scope_multi_evals") > 0.0);
    assert!(doc.get("counters").unwrap().get("scope_multi_pruned_pairs").is_ok());
}
