//! Integration tests for the DAG workload subsystem: chain-equivalence
//! (importing a chain zoo net through the DAG plumbing is bit-identical to
//! the chain path, at every thread count), true multi-branch scheduling
//! end-to-end (GoogLeNet through Scope and the baselines under both
//! segmenters), and the branch-aware DP against exhaustive cut-set ground
//! truth with the real span scheduler.

use scope::arch::McmConfig;
use scope::baselines::{run_all, schedule_segmented, schedule_sequential};
use scope::config::SimOptions;
use scope::dse::exhaustive::exhaustive_cut_segmentations;
use scope::model::dag::DagNetwork;
use scope::model::zoo;
use scope::model::{Layer, Network};
use scope::pipeline::timeline::{boundary_spill, EvalContext};
use scope::scope::{
    schedule_scope_opts, search_segment, search_segments_dag, MethodResult,
    SearchOptions, SegmenterKind, SegmenterOptions,
};
use scope::storage::StoragePolicy;

fn sim(threads: usize, segmenter: SegmenterKind) -> SimOptions {
    SimOptions { samples: 8, threads, segmenter, dp_window: 1, ..Default::default() }
}

fn assert_bitwise_eq(a: &MethodResult, b: &MethodResult, tag: &str) {
    assert_eq!(a.method, b.method, "{tag}");
    assert_eq!(a.eval.error, b.eval.error, "{tag}: validity");
    assert_eq!(
        a.eval.total_cycles.to_bits(),
        b.eval.total_cycles.to_bits(),
        "{tag}: total cycles {} vs {}",
        a.eval.total_cycles,
        b.eval.total_cycles
    );
    assert_eq!(
        a.eval.throughput.to_bits(),
        b.eval.throughput.to_bits(),
        "{tag}: throughput"
    );
    let (ea, eb) = (&a.eval.energy, &b.eval.energy);
    assert_eq!(ea.mac_pj.to_bits(), eb.mac_pj.to_bits(), "{tag}: mac energy");
    assert_eq!(ea.sram_pj.to_bits(), eb.sram_pj.to_bits(), "{tag}: sram energy");
    assert_eq!(ea.nop_pj.to_bits(), eb.nop_pj.to_bits(), "{tag}: nop energy");
    assert_eq!(ea.dram_pj.to_bits(), eb.dram_pj.to_bits(), "{tag}: dram energy");
    assert_eq!(a.schedule, b.schedule, "{tag}: schedule");
}

#[test]
fn chain_equivalence_alexnet_all_methods_bit_identical() {
    // Importing a chain through DagNetwork::from_chain must change
    // *nothing*: every boundary stays legal, no surcharges exist, and all
    // four methods reproduce the chain path bit for bit at 1/2/8 threads.
    let chain = zoo::alexnet();
    let as_dag = DagNetwork::from_chain(&chain).to_network();
    assert!(as_dag.dag.is_some());
    for chiplets in [16usize, 64] {
        let mcm = McmConfig::paper_default(chiplets);
        for threads in [1usize, 2, 8] {
            let opts = sim(threads, SegmenterKind::Balanced);
            let want = run_all(&chain, &mcm, &opts);
            let got = run_all(&as_dag, &mcm, &opts);
            for (a, b) in want.iter().zip(&got) {
                assert_bitwise_eq(a, b, &format!("alexnet@{chiplets}/t{threads}/{}", a.method));
            }
        }
    }
}

#[test]
fn chain_equivalence_resnet50_segmenters_bit_identical() {
    // The deep-net leg of the regression runs through the segmented
    // baseline's per-layer span scheduler (cheap enough to sweep a
    // 54-layer net repeatedly) and sequential's additive path — the same
    // search_segments_dag plumbing Scope uses, with both allocators.
    let chain = zoo::resnet50();
    let as_dag = DagNetwork::from_chain(&chain).to_network();
    for chiplets in [16usize, 64] {
        let mcm = McmConfig::paper_default(chiplets);
        for threads in [1usize, 2, 8] {
            for kind in [SegmenterKind::Balanced, SegmenterKind::Dp] {
                let opts = sim(threads, kind);
                let tag = format!("resnet50@{chiplets}/t{threads}/{kind:?}");
                assert_bitwise_eq(
                    &schedule_segmented(&chain, &mcm, &opts),
                    &schedule_segmented(&as_dag, &mcm, &opts),
                    &format!("{tag}/segmented"),
                );
                assert_bitwise_eq(
                    &schedule_sequential(&chain, &mcm, &opts),
                    &schedule_sequential(&as_dag, &mcm, &opts),
                    &format!("{tag}/sequential"),
                );
            }
        }
    }
}

/// A small true-residual net (two identity-skip blocks + tail): cheap
/// enough to run the real Algorithm-1 scheduler over every cut subset.
fn small_skip_net() -> Network {
    let mut g = DagNetwork::builder("miniskip", (16, 16, 16));
    let stem = g.node(Layer::conv("stem", 16, 16, 16, 16, 3, 1, 1), &[]);
    let mut x = stem;
    for b in 0..2 {
        let c1 = g.node(Layer::conv(&format!("b{b}.c1"), 16, 16, 16, 16, 3, 1, 1), &[x]);
        let c2 = g.node(Layer::conv(&format!("b{b}.c2"), 16, 16, 16, 16, 3, 1, 1), &[c1]);
        x = g.node(Layer::add_merge(&format!("b{b}.add"), 16, 16, 16), &[c2, x]);
    }
    g.node(Layer::conv("tail", 16, 16, 16, 32, 3, 1, 1), &[x]);
    g.build().to_network()
}

#[test]
fn dag_dp_matches_exhaustive_cut_ground_truth_with_real_scheduler() {
    let net = small_skip_net();
    let mcm = McmConfig::paper_default(8);
    let opts = SimOptions { samples: 4, threads: 1, ..Default::default() };
    let ctx = EvalContext {
        net: &net,
        mcm: &mcm,
        opts: &opts,
        policy: StoragePolicy::Distributed,
        dram_fallback: true,
    };
    let provider = |lo: usize, hi: usize| {
        search_segment(&ctx, lo, hi, opts.samples, SearchOptions::default())
            .map(|s| (s.schedule, s.latency))
    };
    let seg_opts = SegmenterOptions {
        kind: SegmenterKind::Dp,
        dp_window: 0,
        ..SegmenterOptions::default()
    };
    let dp = search_segments_dag(
        &net,
        &mcm,
        opts.samples,
        1,
        net.len(),
        usize::MAX,
        1,
        seg_opts,
        &provider,
    )
    .expect("dp result");
    let info = net.dag.as_ref().unwrap();
    assert!(
        dp.bounds[1..dp.bounds.len() - 1].iter().all(|&b| info.is_cut(b)),
        "bounds {:?}",
        dp.bounds
    );
    // ground truth: every subset of the clean cuts, spans costed by the
    // identical scheduler + the identical boundary spill
    let ex = exhaustive_cut_segmentations(
        net.len(),
        &info.cut_positions(),
        1,
        net.len(),
        usize::MAX,
        |lo, hi| {
            provider(lo, hi).map(|(_, lat)| {
                lat + if lo > 0 { boundary_spill(&net, &mcm, lo, opts.samples).cycles } else { 0.0 }
            })
        },
    )
    .expect("exhaustive result");
    assert_eq!(
        dp.total_latency.to_bits(),
        ex.1.to_bits(),
        "dp {} (bounds {:?}) vs exhaustive {} (bounds {:?})",
        dp.total_latency,
        dp.bounds,
        ex.1,
        ex.0
    );
}

#[test]
fn googlenet_runs_end_to_end_through_every_method_and_both_segmenters() {
    let net = zoo::googlenet();
    let mcm = McmConfig::paper_default(16);
    let info = net.dag.as_ref().expect("googlenet is a DAG workload");
    // bounded Scope search keeps the 67-node DAG tractable in a test;
    // the CI smoke run exercises the full default search in release mode
    let sopts = SearchOptions {
        max_clusters: 2,
        refine_bounds: false,
        max_region_iters: 8,
        ..Default::default()
    };
    for kind in [SegmenterKind::Balanced, SegmenterKind::Dp] {
        let opts = SimOptions { samples: 2, dp_window: 1, segmenter: kind, ..Default::default() };
        let scope_r = schedule_scope_opts(&net, &mcm, &opts, sopts);
        assert!(scope_r.eval.is_valid(), "{kind:?}: {:?}", scope_r.eval.error);
        assert!(scope_r.throughput() > 0.0);
        let sched = scope_r.schedule.as_ref().unwrap();
        for seg in &sched.segments[..sched.segments.len() - 1] {
            assert!(info.is_cut(seg.hi), "{kind:?}: boundary {} off-cut", seg.hi);
        }

        let seg_r = schedule_segmented(&net, &mcm, &opts);
        assert!(seg_r.eval.is_valid(), "{kind:?}: {:?}", seg_r.eval.error);
        // per-layer stages: ≥ ceil(67/16) segments, all on cuts
        let seg_sched = seg_r.schedule.as_ref().unwrap();
        assert!(seg_sched.segments.len() >= net.len().div_ceil(mcm.chiplets));
        for seg in &seg_sched.segments[..seg_sched.segments.len() - 1] {
            assert!(info.is_cut(seg.hi), "{kind:?}: segmented boundary {} off-cut", seg.hi);
        }

        let seq_r = schedule_sequential(&net, &mcm, &opts);
        assert!(seq_r.eval.is_valid(), "{kind:?}: {:?}", seq_r.eval.error);

        // full pipeline needs a chiplet per stage: 67 nodes > 16 chiplets
        // reports the paper's failure mode instead of crashing
        let fp = scope::baselines::schedule_full_pipeline(&net, &mcm, &opts);
        assert!(!fp.eval.is_valid());
    }
}

#[test]
fn dag_zoo_dp_never_worse_than_balanced_through_segmented() {
    // The identical-allocator dominance property extends to the DAG zoo:
    // the DP window (in cut-domain steps) always contains the snapped
    // balanced seed.
    for net in zoo::dag_networks() {
        for chiplets in [16usize, 32] {
            let mcm = McmConfig::paper_default(chiplets);
            let bal = schedule_segmented(&net, &mcm, &sim(0, SegmenterKind::Balanced));
            if !bal.eval.is_valid() {
                continue;
            }
            let dp = schedule_segmented(&net, &mcm, &sim(0, SegmenterKind::Dp));
            assert!(
                dp.eval.is_valid(),
                "{}@{chiplets}: dp invalid where balanced is valid: {:?}",
                net.name,
                dp.eval.error
            );
            assert!(
                dp.throughput() >= bal.throughput() * 0.999,
                "{}@{chiplets}: dp {} < balanced {}",
                net.name,
                dp.throughput(),
                bal.throughput()
            );
        }
    }
}

#[test]
fn dag_segmented_is_bit_identical_across_threads() {
    // GoogLeNet through the segmented baseline's DP path: the span
    // prefetch fans across the pool, the cut restriction and boundary
    // surcharges must not perturb determinism.
    let net = zoo::googlenet();
    let mcm = McmConfig::paper_default(16);
    let serial = schedule_segmented(&net, &mcm, &sim(1, SegmenterKind::Dp));
    assert!(serial.eval.is_valid(), "{:?}", serial.eval.error);
    for threads in [2usize, 8] {
        let par = schedule_segmented(&net, &mcm, &sim(threads, SegmenterKind::Dp));
        assert_eq!(serial.schedule, par.schedule, "threads={threads}: schedule drifted");
        assert_eq!(
            serial.eval.total_cycles.to_bits(),
            par.eval.total_cycles.to_bits(),
            "threads={threads}: latency drifted"
        );
    }
}
