"""Layer-2 JAX model: ScopeNet, the functional workload for the merged pipeline.

ScopeNet is a small Darknet-style CNN whose layers are grouped into the same
kind of *clusters* the Scope scheduler produces (a cluster = a set of merged
layers executed by one chiplet region).  ``aot.py`` lowers

  * one HLO module per cluster               -> the units the rust
    coordinator pipelines across regions,
  * one HLO module for the whole network     -> the golden reference the
    coordinator checks its pipelined output against,
  * ISP-sharded per-layer modules of one cluster -> the units for the
    functional input-shared-partitioning demo (weights split on Cout,
    activations replicated; the coordinator performs the Table-II
    all-gather between the shards).

Every conv/fc goes through the Layer-1 Pallas kernel (kernels.conv /
kernels.matmul_pe), so the emitted HLO contains the kernel's tiling and the
three layers of the stack are exercised by one artifact set.

Weights are generated deterministically from a seed and enter the lowered
modules as *runtime parameters* (``*_weights_in`` variants): the rust
coordinator owns the weight state, mirroring the paper's distributed weight
buffering (§III-B). (Also load-bearing: xla_extension 0.5.1 miscompiles
Pallas interpret loops over large HLO constants — see
``cluster_fn_weights_in``.)
"""

from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp

from .kernels import conv as kconv
from .kernels import matmul_pe as kmm
from .kernels import ref as kref

# ---------------------------------------------------------------------------
# Architecture description
# ---------------------------------------------------------------------------

#: Input geometry (H, W, C).  Small enough that interpret-mode pallas stays
#: fast on CPU, deep enough to make a 3-stage merged pipeline meaningful.
INPUT_SHAPE = (16, 16, 3)
NUM_CLASSES = 10

#: Conv layer table: (name, cout, k, stride, pad, pool_after)
#: A "pool_after" layer ends with a 2x2/2 maxpool (fused into the same
#: cluster stage, as the paper folds cheap layers into their cluster).
CONV_LAYERS = (
    ("conv1", 16, 3, 1, 1, False),
    ("conv2", 16, 3, 1, 1, True),   # 16x16 -> 8x8
    ("conv3", 32, 3, 1, 1, False),
    ("conv4", 32, 3, 1, 1, True),   # 8x8 -> 4x4
    ("conv5", 64, 3, 1, 1, False),
)

#: Cluster composition: the merged-pipeline grouping the coordinator runs.
#: Mirrors a Scope schedule for this net: balanced MAC load per cluster.
CLUSTERS = (
    ("conv1", "conv2"),
    ("conv3", "conv4"),
    ("conv5", "head"),
)

#: The cluster whose layers are additionally emitted as ISP shards.
ISP_CLUSTER = 1
ISP_WAYS = 2


# ---------------------------------------------------------------------------
# Parameters
# ---------------------------------------------------------------------------


def init_params(seed: int = 0) -> dict[str, jax.Array]:
    """Deterministic He-style initialisation for all layers."""
    key = jax.random.PRNGKey(seed)
    params: dict[str, jax.Array] = {}
    cin = INPUT_SHAPE[2]
    for name, cout, k, _stride, _pad, _pool in CONV_LAYERS:
        key, kw_, kb_ = jax.random.split(key, 3)
        fan_in = k * k * cin
        params[f"{name}.w"] = (
            jax.random.normal(kw_, (k, k, cin, cout), jnp.float32)
            * jnp.sqrt(2.0 / fan_in)
        )
        params[f"{name}.b"] = jax.random.normal(kb_, (cout,), jnp.float32) * 0.01
        cin = cout
    key, kw_, kb_ = jax.random.split(key, 3)
    params["fc.w"] = (
        jax.random.normal(kw_, (cin, NUM_CLASSES), jnp.float32)
        * jnp.sqrt(2.0 / cin)
    )
    params["fc.b"] = jax.random.normal(kb_, (NUM_CLASSES,), jnp.float32) * 0.01
    return params


def _layer_table() -> dict[str, tuple]:
    return {name: spec for spec in CONV_LAYERS for name in (spec[0],)}


# ---------------------------------------------------------------------------
# Layer application (pallas path and reference path)
# ---------------------------------------------------------------------------


def apply_conv(
    params: dict[str, jax.Array],
    name: str,
    x: jax.Array,
    *,
    use_pallas: bool = True,
    cout_slice: tuple[int, int] | None = None,
) -> jax.Array:
    """Run one named conv layer (+ fused pool if the table says so).

    ``cout_slice=(lo, hi)`` applies ISP: only weights for output channels
    [lo, hi) are used -- the input is the full activation (replicated), the
    output is the channel shard, exactly the paper's input-shared
    partitioning.
    """
    _, cout, k, stride, pad, pool = _layer_table()[name]
    w, b = params[f"{name}.w"], params[f"{name}.b"]
    if cout_slice is not None:
        lo, hi = cout_slice
        w, b = w[..., lo:hi], b[lo:hi]
    fn = kconv.conv2d_pe if use_pallas else kref.conv2d_ref
    y = fn(x, w, b, stride=stride, pad=pad, relu=True)
    if pool:
        y = kref.maxpool2_ref(y)
    return y


def apply_head(
    params: dict[str, jax.Array], x: jax.Array, *, use_pallas: bool = True
) -> jax.Array:
    """Global average pool + fully connected classifier."""
    pooled = kref.gap_ref(x)
    if use_pallas:
        y = kmm.matmul_pe_bias_act(pooled[None, :], params["fc.w"], params["fc.b"])
        return y[0]
    return kref.matmul_ref(pooled[None, :], params["fc.w"])[0] + params["fc.b"]


def _apply_member(
    params: dict[str, jax.Array], member: str, x: jax.Array, *, use_pallas: bool
) -> jax.Array:
    if member == "head":
        return apply_head(params, x, use_pallas=use_pallas)
    return apply_conv(params, member, x, use_pallas=use_pallas)


# ---------------------------------------------------------------------------
# Cluster / full-network functions (what aot.py lowers)
# ---------------------------------------------------------------------------


def cluster_fn(
    params: dict[str, jax.Array], cluster_idx: int, *, use_pallas: bool = True
) -> Callable[[jax.Array], tuple[jax.Array]]:
    """The function one pipeline region executes: its cluster's merged layers."""
    members = CLUSTERS[cluster_idx]

    def fn(x: jax.Array) -> tuple[jax.Array]:
        for member in members:
            x = _apply_member(params, member, x, use_pallas=use_pallas)
        return (x,)

    return fn


def member_param_names(member: str) -> list[str]:
    """Parameter tensors a layer consumes, in AOT calling order."""
    if member == "head":
        return ["fc.w", "fc.b"]
    return [f"{member}.w", f"{member}.b"]


def cluster_param_names(cluster_idx: int) -> list[str]:
    """All parameter names of a cluster, in AOT calling order."""
    names: list[str] = []
    for member in CLUSTERS[cluster_idx]:
        names.extend(member_param_names(member))
    return names


def cluster_fn_weights_in(
    cluster_idx: int, *, use_pallas: bool = True
) -> tuple[Callable[..., tuple[jax.Array]], list[str]]:
    """Like :func:`cluster_fn`, but weights enter as *runtime parameters*
    `fn(x, *weights)` instead of baked constants.

    Two reasons: (a) architecturally, the rust coordinator owns the weight
    state (the paper's distributed weight buffering lives at L3); (b) the
    image's xla_extension 0.5.1 runtime miscompiles Pallas interpret loops
    whose operands are large HLO constants (all-zero outputs) — verified by
    bisection; weights-as-parameters sidesteps the bug. Returns
    `(fn, param_names)`; callers pass arrays in `param_names` order.
    """
    members = CLUSTERS[cluster_idx]
    names = cluster_param_names(cluster_idx)

    def fn(x: jax.Array, *weights: jax.Array) -> tuple[jax.Array]:
        assert len(weights) == len(names)
        local = dict(zip(names, weights))
        for member in members:
            x = _apply_member(local, member, x, use_pallas=use_pallas)
        return (x,)

    return fn, names


def full_fn_weights_in(
    *, use_pallas: bool = True
) -> tuple[Callable[..., tuple[jax.Array]], list[str]]:
    """Whole network with weights as runtime parameters (see
    :func:`cluster_fn_weights_in`)."""
    all_names: list[str] = []
    for idx in range(len(CLUSTERS)):
        all_names.extend(cluster_param_names(idx))

    def fn(x: jax.Array, *weights: jax.Array) -> tuple[jax.Array]:
        assert len(weights) == len(all_names)
        local = dict(zip(all_names, weights))
        for members in CLUSTERS:
            for member in members:
                x = _apply_member(local, member, x, use_pallas=use_pallas)
        return (x,)

    return fn, all_names


def full_fn(
    params: dict[str, jax.Array], *, use_pallas: bool = True
) -> Callable[[jax.Array], tuple[jax.Array]]:
    """The whole network end to end (golden reference module)."""

    def fn(x: jax.Array) -> tuple[jax.Array]:
        for cluster_idx in range(len(CLUSTERS)):
            (x,) = cluster_fn(params, cluster_idx, use_pallas=use_pallas)(x)
        return (x,)

    return fn


def isp_shard_params(
    params: dict[str, jax.Array], layer: str, shard: int, ways: int = ISP_WAYS
) -> tuple[jax.Array, jax.Array]:
    """The (w, b) slice an ISP shard owns: output channels [lo, hi)."""
    _, cout, *_ = _layer_table()[layer]
    if cout % ways:
        raise ValueError(f"{layer}: cout={cout} not divisible into {ways} ISP shards")
    width = cout // ways
    lo, hi = shard * width, (shard + 1) * width
    return params[f"{layer}.w"][..., lo:hi], params[f"{layer}.b"][lo:hi]


def isp_shard_fn_weights_in(
    layer: str, *, use_pallas: bool = True
) -> Callable[..., tuple[jax.Array]]:
    """ISP shard with its weight slice as runtime parameters:
    `fn(x, w_shard, b_shard)`. The caller (aot.py / the coordinator) feeds
    the slice from :func:`isp_shard_params`."""
    spec = _layer_table()[layer]
    _, _cout, _k, stride, pad, pool = spec

    def fn(x: jax.Array, w: jax.Array, b: jax.Array) -> tuple[jax.Array]:
        conv = kconv.conv2d_pe if use_pallas else kref.conv2d_ref
        y = conv(x, w, b, stride=stride, pad=pad, relu=True)
        if pool:
            y = kref.maxpool2_ref(y)
        return (y,)

    return fn


def isp_shard_fn(
    params: dict[str, jax.Array],
    layer: str,
    shard: int,
    ways: int = ISP_WAYS,
    *,
    use_pallas: bool = True,
) -> Callable[[jax.Array], tuple[jax.Array]]:
    """One ISP shard of one conv layer: full input, Cout/ways output channels.

    The rust coordinator replicates the input to ``ways`` workers, runs each
    shard, and concatenates the channel shards -- the Table-II
    "(R-1) x Output" ISP->ISP all-gather, performed over its channel NoP.
    """
    _, cout, *_ = _layer_table()[layer]
    if cout % ways:
        raise ValueError(f"{layer}: cout={cout} not divisible into {ways} ISP shards")
    width = cout // ways
    lo, hi = shard * width, (shard + 1) * width

    def fn(x: jax.Array) -> tuple[jax.Array]:
        return (apply_conv(params, layer, x, use_pallas=use_pallas,
                           cout_slice=(lo, hi)),)

    return fn


# ---------------------------------------------------------------------------
# Shape bookkeeping (consumed by aot.py for the artifact manifest)
# ---------------------------------------------------------------------------


@functools.lru_cache(maxsize=None)
def cluster_io_shapes() -> tuple[tuple[tuple[int, ...], tuple[int, ...]], ...]:
    """(input_shape, output_shape) per cluster, computed by abstract eval."""
    shapes = []
    params = init_params(0)
    x_shape: tuple[int, ...] = INPUT_SHAPE
    for idx in range(len(CLUSTERS)):
        out = jax.eval_shape(
            lambda x, idx=idx: cluster_fn(params, idx, use_pallas=False)(x),
            jax.ShapeDtypeStruct(x_shape, jnp.float32),
        )[0]
        shapes.append((x_shape, tuple(out.shape)))
        x_shape = tuple(out.shape)
    return tuple(shapes)
