"""Layer-1 Pallas kernel: the chiplet PE-array matmul.

This kernel is the compute hot-spot of the whole stack.  Its tiling mirrors
one Scope chiplet (Table III of the paper) under the weight-stationary
dataflow:

  * the N dimension (output channels) is tiled by ``bn`` = 128, matching the
    16 PEs x 8 lanes = 128 lane-level output channels of a chiplet (and,
    conveniently, the MXU width on a real TPU);
  * the K dimension (the Cin*Kh*Kw reduction) is tiled by ``bk`` = 8,
    matching the 8 MACs per lane that reduce along input channels;
  * the M dimension (output pixels) streams through the array in strips of
    ``bm`` rows, playing the role of the temporal pixel loop.

BlockSpec expresses the HBM<->VMEM schedule: one (bm, bk) activation strip
and one (bk, bn) weight tile are resident per grid step -- the analogue of
the paper's global-buffer / per-PE weight-buffer residency.  ``interpret=True``
is mandatory here: the artifacts must run on the CPU PJRT client (real-TPU
lowering emits a Mosaic custom-call the CPU plugin cannot execute).

Correctness oracle: ``kernels.ref.matmul_ref`` (pure jnp), enforced by
``python/tests/test_kernel.py`` (hypothesis sweeps shapes and seeds).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Chiplet-derived default tile sizes (see module docstring / DESIGN.md
# "Hardware-Adaptation").
PE_LANES = 128  # 4x4 PEs * 8 lanes: spatial output-channel slots
MACS_PER_LANE = 8  # reduction width per lane
DEFAULT_BM = 8  # pixel strip streamed per grid step


def _ceil_to(x: int, m: int) -> int:
    return (x + m - 1) // m * m


def _mm_kernel(x_ref, w_ref, o_ref):
    """One grid step: multiply-accumulate a (bm,bk) x (bk,bn) tile pair.

    Grid axis 2 walks the reduction; the output block is revisited for every
    k step (index map ignores k), so we accumulate in place, initialising on
    the first step -- exactly how a weight-stationary PE accumulates partial
    sums across input-channel tiles.
    """
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        x_ref[...], w_ref[...], preferred_element_type=jnp.float32
    )


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk"))
def matmul_pe(
    x: jax.Array,
    w: jax.Array,
    *,
    bm: int = DEFAULT_BM,
    bn: int = PE_LANES,
    bk: int = MACS_PER_LANE,
) -> jax.Array:
    """Compute ``x @ w`` with the PE-array tiling.

    Args:
      x: (M, K) float32 activations (output pixels x reduction).
      w: (K, N) float32 weights (reduction x output channels).
      bm/bn/bk: tile sizes; defaults mirror the paper's chiplet.

    Returns:
      (M, N) float32, bit-accumulated in f32 (the paper accumulates in
      24-bit; f32 strictly contains that range).
    """
    if x.ndim != 2 or w.ndim != 2:
        raise ValueError(f"matmul_pe expects 2-D operands, got {x.shape} @ {w.shape}")
    m, k = x.shape
    k2, n = w.shape
    if k != k2:
        raise ValueError(f"reduction mismatch: {x.shape} @ {w.shape}")

    # Pad every dimension to its tile multiple; the quantization waste this
    # padding represents is exactly the utilization loss the L3 cost model
    # charges (cost/compute.rs uses the same ceil arithmetic).
    mp, kp, np_ = _ceil_to(m, bm), _ceil_to(k, bk), _ceil_to(n, bn)
    xp = jnp.pad(x.astype(jnp.float32), ((0, mp - m), (0, kp - k)))
    wp = jnp.pad(w.astype(jnp.float32), ((0, kp - k), (0, np_ - n)))

    grid = (mp // bm, np_ // bn, kp // bk)
    out = pl.pallas_call(
        _mm_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        interpret=True,  # CPU-PJRT execution path; see module docstring
    )(xp, wp)
    return out[:m, :n]


def matmul_pe_bias_act(
    x: jax.Array,
    w: jax.Array,
    b: jax.Array | None = None,
    *,
    relu: bool = False,
    bm: int = DEFAULT_BM,
    bn: int = PE_LANES,
    bk: int = MACS_PER_LANE,
) -> jax.Array:
    """matmul_pe followed by the chiplet's post-processing path (bias+ReLU).

    The paper's chiplet aggregates PE partial sums on the NoC and applies
    activation on the way to the global buffer; here that epilogue is plain
    jnp fused by XLA into the same HLO module.
    """
    y = matmul_pe(x, w, bm=bm, bn=bn, bk=bk)
    if b is not None:
        y = y + b[None, :]
    if relu:
        y = jnp.maximum(y, 0.0)
    return y


def vmem_footprint_bytes(bm: int = DEFAULT_BM, bn: int = PE_LANES, bk: int = MACS_PER_LANE) -> int:
    """Estimated resident VMEM bytes per grid step (f32).

    One activation strip + one weight tile + one output block.  Used by the
    perf pass (EXPERIMENTS.md SPerf) to check the tiling against the 1 MiB
    chiplet weight-buffer budget it stands in for.
    """
    return 4 * (bm * bk + bk * bn + bm * bn)


def mxu_utilization_estimate(m: int, k: int, n: int,
                             bm: int = DEFAULT_BM, bn: int = PE_LANES,
                             bk: int = MACS_PER_LANE) -> float:
    """Fraction of issued MACs that are useful for an (m,k,n) problem.

    This is the same ceil-quantization the L3 compute cost model charges;
    surfaced here so pytest can assert the two layers agree.
    """
    useful = m * k * n
    issued = _ceil_to(m, bm) * _ceil_to(k, bk) * _ceil_to(n, bn)
    return useful / issued
