"""Layer-1 Pallas kernels for the Scope chiplet compute hot-spot.

``matmul_pe`` — the weight-stationary PE-array matmul (the hot-spot).
``conv`` — im2col convolution layered on matmul_pe.
``ref`` — pure-jnp oracles (never pallas).
"""

from . import conv, matmul_pe, ref  # noqa: F401
