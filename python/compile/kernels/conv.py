"""Convolution on the PE-array kernel via im2col.

The paper's chiplets execute conv layers as weight-stationary matmuls over
an im2col-style unrolling (output pixels x (Cin*Kh*Kw) reduction).  We do
the same: ``im2col`` lays out patches so the reduction ordering matches a
``(Kh, Kw, Cin, Cout) -> (Kh*Kw*Cin, Cout)`` weight reshape, then the L1
Pallas kernel does the matmul.  ``conv2d_pe`` is what the L2 model calls.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from . import matmul_pe as mm


def out_size(size: int, k: int, stride: int, pad: int) -> int:
    """Output spatial extent of a conv/pool window sweep."""
    return (size + 2 * pad - k) // stride + 1


def im2col(x: jax.Array, kh: int, kw: int, stride: int = 1, pad: int = 0) -> jax.Array:
    """Unroll (H, W, C) into (Ho*Wo, Kh*Kw*C) patch rows.

    Patch element ordering is (ki, kj) major, channel minor -- identical to
    flattening a (Kh, Kw, C, Cout) weight tensor over its first three axes,
    so ``im2col(x) @ w.reshape(-1, Cout)`` equals the convolution.
    """
    if x.ndim != 3:
        raise ValueError(f"im2col expects (H, W, C), got {x.shape}")
    h, w, c = x.shape
    ho, wo = out_size(h, kh, stride, pad), out_size(w, kw, stride, pad)
    xp = jnp.pad(x, ((pad, pad), (pad, pad), (0, 0)))
    patches = []
    for ki in range(kh):
        for kj in range(kw):
            patches.append(
                jax.lax.slice(
                    xp,
                    (ki, kj, 0),
                    (ki + (ho - 1) * stride + 1, kj + (wo - 1) * stride + 1, c),
                    (stride, stride, 1),
                )
            )
    # (Ho, Wo, Kh*Kw, C) -> (Ho*Wo, Kh*Kw*C)
    stacked = jnp.stack(patches, axis=2)
    return stacked.reshape(ho * wo, kh * kw * c)


def conv2d_pe(
    x: jax.Array,
    w: jax.Array,
    b: jax.Array | None = None,
    *,
    stride: int = 1,
    pad: int = 0,
    relu: bool = False,
) -> jax.Array:
    """2-D convolution through the Pallas PE-array kernel.

    Args:
      x: (H, W, Cin) activation (single sample -- the pipeline streams
         samples one at a time, per the paper's per-sample cluster pipeline).
      w: (Kh, Kw, Cin, Cout) weights.
      b: optional (Cout,) bias.
      stride/pad: symmetric conv geometry.
      relu: fuse the chiplet's ReLU epilogue.

    Returns:
      (Ho, Wo, Cout) float32.
    """
    if w.ndim != 4:
        raise ValueError(f"conv2d_pe expects (Kh,Kw,Cin,Cout) weights, got {w.shape}")
    kh, kw, cin, cout = w.shape
    if x.shape[-1] != cin:
        raise ValueError(f"channel mismatch: x {x.shape} vs w {w.shape}")
    h, wdim, _ = x.shape
    ho, wo = out_size(h, kh, stride, pad), out_size(wdim, kw, stride, pad)
    cols = im2col(x, kh, kw, stride, pad)
    y = mm.matmul_pe_bias_act(cols, w.reshape(kh * kw * cin, cout), b, relu=relu)
    return y.reshape(ho, wo, cout)
