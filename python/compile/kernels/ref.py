"""Pure-jnp correctness oracles for the Pallas kernels.

Never calls pallas; this is the trusted reference the hypothesis sweeps in
``python/tests/test_kernel.py`` compare against.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def matmul_ref(x: jax.Array, w: jax.Array) -> jax.Array:
    """Plain f32 matmul."""
    return jnp.dot(x.astype(jnp.float32), w.astype(jnp.float32),
                   preferred_element_type=jnp.float32)


def conv2d_ref(
    x: jax.Array,
    w: jax.Array,
    b: jax.Array | None = None,
    *,
    stride: int = 1,
    pad: int = 0,
    relu: bool = False,
) -> jax.Array:
    """Reference conv via lax.conv_general_dilated (NHWC / HWIO)."""
    y = jax.lax.conv_general_dilated(
        x[None].astype(jnp.float32),
        w.astype(jnp.float32),
        window_strides=(stride, stride),
        padding=[(pad, pad), (pad, pad)],
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )[0]
    if b is not None:
        y = y + b[None, None, :]
    if relu:
        y = jnp.maximum(y, 0.0)
    return y


def maxpool2_ref(x: jax.Array) -> jax.Array:
    """2x2/stride-2 max pool over (H, W, C); H and W must be even."""
    h, w, c = x.shape
    return jnp.max(x.reshape(h // 2, 2, w // 2, 2, c), axis=(1, 3))


def gap_ref(x: jax.Array) -> jax.Array:
    """Global average pool (H, W, C) -> (C,)."""
    return jnp.mean(x, axis=(0, 1))
