"""AOT emitter: lower the L2 model (with its L1 Pallas kernels) to HLO text.

HLO *text* -- NOT ``lowered.compile().serialize()`` and NOT serialized
HloModuleProto -- is the interchange format: jax >= 0.5 emits protos with
64-bit instruction ids which the rust side's xla_extension 0.5.1 rejects
(``proto.id() <= INT_MAX``); the text parser reassigns ids and round-trips
cleanly (see /opt/xla-example/README.md).

Run once at build time (``make artifacts``); python is never on the rust
request path.  Emits into ``artifacts/``:

  scopenet_cluster{i}.hlo.txt      one module per pipeline cluster
  scopenet_cluster{i}.params.bin   that cluster's weights (f32 LE, in the
                                   manifest's parameter order)
  scopenet_full.hlo.txt/.params.bin  golden whole-network module
  model.hlo.txt                    alias of the full module (Makefile stamp)
  scopenet_*_isp{j}of{W}.hlo.txt/.params.bin
                                   ISP channel-shard modules (functional
                                   partitioning demo)
  matmul_pe_MxKxN.hlo.txt          standalone L1 kernel (runtime microbench)
  golden_inputs.bin/.golden_outputs.bin
                                   little-endian f32 validation tensors,
                                   outputs computed with the pure-jnp
                                   reference path (cross-checks the kernel
                                   at the artifact level)
  manifest.json                    shapes + file index for the rust loader

Weights enter each module as runtime parameters, not baked constants: the
rust coordinator owns the weight state (paper §III-B), and xla_extension
0.5.1 miscompiles Pallas interpret loops over large constants (verified by
bisection — constants-variant modules return all-zero activations).
"""

from __future__ import annotations

import argparse
import json
import pathlib
import sys

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model
from .kernels import matmul_pe as kmm

GOLDEN_BATCH = 4
GOLDEN_SEED = 42
MICRO_MKN = (64, 72, 128)  # standalone kernel artifact shape (M, K, N)


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (see module docstring)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_fn(fn, in_shapes: list[tuple[int, ...]]) -> str:
    specs = [jax.ShapeDtypeStruct(s, jnp.float32) for s in in_shapes]
    return to_hlo_text(jax.jit(fn).lower(*specs))


def _write(outdir: pathlib.Path, name: str, text: str) -> str:
    path = outdir / name
    path.write_text(text)
    print(f"  wrote {path} ({len(text)} chars)")
    return name


def _write_params(
    outdir: pathlib.Path, stem: str, arrays: list[jax.Array]
) -> tuple[str, list[dict]]:
    """Write a module's parameter arrays (f32 LE, concatenated in calling
    order) and return (filename, per-param metadata)."""
    fname = f"{stem}.params.bin"
    with open(outdir / fname, "wb") as f:
        for a in arrays:
            np.asarray(a, dtype="<f4").tofile(f)
    meta = [{"shape": list(a.shape)} for a in arrays]
    print(f"  wrote {outdir / fname} ({len(arrays)} tensors)")
    return fname, meta


def build_artifacts(outdir: pathlib.Path, seed: int = 0) -> dict:
    outdir.mkdir(parents=True, exist_ok=True)
    params = model.init_params(seed)
    io_shapes = model.cluster_io_shapes()
    manifest: dict = {
        "seed": seed,
        "input_shape": list(model.INPUT_SHAPE),
        "num_classes": model.NUM_CLASSES,
        "golden_batch": GOLDEN_BATCH,
        "clusters": [],
        "isp": {"cluster": model.ISP_CLUSTER, "ways": model.ISP_WAYS, "layers": []},
        "micro": {},
    }

    # --- per-cluster modules -------------------------------------------------
    for idx, members in enumerate(model.CLUSTERS):
        in_shape, out_shape = io_shapes[idx]
        fn, names = model.cluster_fn_weights_in(idx)
        weights = [params[n] for n in names]
        stem = f"scopenet_cluster{idx}"
        fname = _write(
            outdir,
            f"{stem}.hlo.txt",
            lower_fn(fn, [in_shape] + [tuple(w.shape) for w in weights]),
        )
        params_file, params_meta = _write_params(outdir, stem, weights)
        manifest["clusters"].append(
            {
                "index": idx,
                "members": list(members),
                "file": fname,
                "params_file": params_file,
                "params": params_meta,
                "input_shape": list(in_shape),
                "output_shape": list(out_shape),
            }
        )

    # --- golden full module --------------------------------------------------
    full_fn_p, full_names = model.full_fn_weights_in()
    full_weights = [params[n] for n in full_names]
    full_text = lower_fn(
        full_fn_p, [model.INPUT_SHAPE] + [tuple(w.shape) for w in full_weights]
    )
    full_params_file, full_params_meta = _write_params(
        outdir, "scopenet_full", full_weights
    )
    manifest["full"] = {
        "file": _write(outdir, "scopenet_full.hlo.txt", full_text),
        "params_file": full_params_file,
        "params": full_params_meta,
        "input_shape": list(model.INPUT_SHAPE),
        "output_shape": [model.NUM_CLASSES],
    }
    _write(outdir, "model.hlo.txt", full_text)  # Makefile stamp / alias

    # --- ISP shard modules (functional partitioning demo) -------------------
    isp_members = [
        m for m in model.CLUSTERS[model.ISP_CLUSTER] if m != "head"
    ]
    shard_in = io_shapes[model.ISP_CLUSTER][0]
    for layer in isp_members:
        shards = []
        shard_params = []
        layer_out = None
        fn = model.isp_shard_fn_weights_in(layer)
        for j in range(model.ISP_WAYS):
            w, b = model.isp_shard_params(params, layer, j)
            out = jax.eval_shape(
                fn,
                jax.ShapeDtypeStruct(shard_in, jnp.float32),
                jax.ShapeDtypeStruct(w.shape, jnp.float32),
                jax.ShapeDtypeStruct(b.shape, jnp.float32),
            )[0]
            layer_out = tuple(out.shape)
            stem = f"scopenet_{layer}_isp{j}of{model.ISP_WAYS}"
            shards.append(
                _write(
                    outdir,
                    f"{stem}.hlo.txt",
                    lower_fn(fn, [shard_in, tuple(w.shape), tuple(b.shape)]),
                )
            )
            pfile, pmeta = _write_params(outdir, stem, [w, b])
            shard_params.append({"params_file": pfile, "params": pmeta})
        full_out = (layer_out[0], layer_out[1], layer_out[2] * model.ISP_WAYS)
        manifest["isp"]["layers"].append(
            {
                "layer": layer,
                "files": shards,
                "shard_params": shard_params,
                "input_shape": list(shard_in),
                "shard_output_shape": list(layer_out),
                "full_output_shape": list(full_out),
            }
        )
        # next layer in the cluster consumes the gathered full activation
        shard_in = full_out

    # --- standalone L1 kernel (runtime microbench) ---------------------------
    m, k, n = MICRO_MKN
    manifest["micro"] = {
        "file": _write(
            outdir,
            f"matmul_pe_{m}x{k}x{n}.hlo.txt",
            lower_fn(lambda x, w: (kmm.matmul_pe(x, w),), [(m, k), (k, n)]),
        ),
        "m": m,
        "k": k,
        "n": n,
    }

    # --- golden tensors (reference path, cross-checks pallas artifacts) -----
    key = jax.random.PRNGKey(GOLDEN_SEED)
    xs = jax.random.normal(key, (GOLDEN_BATCH, *model.INPUT_SHAPE), jnp.float32)
    ref = model.full_fn(params, use_pallas=False)
    ys = jnp.stack([ref(xs[i])[0] for i in range(GOLDEN_BATCH)])
    np.asarray(xs, dtype="<f4").tofile(outdir / "golden_inputs.bin")
    np.asarray(ys, dtype="<f4").tofile(outdir / "golden_outputs.bin")
    print(f"  wrote golden tensors: {xs.shape} -> {ys.shape}")

    (outdir / "manifest.json").write_text(json.dumps(manifest, indent=2))
    print(f"  wrote {outdir / 'manifest.json'}")
    return manifest


def self_check(seed: int = 0) -> None:
    """Composition check: clusters chained == full network (pallas path)."""
    params = model.init_params(seed)
    key = jax.random.PRNGKey(7)
    x = jax.random.normal(key, model.INPUT_SHAPE, jnp.float32)
    chained = x
    for idx in range(len(model.CLUSTERS)):
        (chained,) = jax.jit(model.cluster_fn(params, idx))(chained)
    (full,) = jax.jit(model.full_fn(params))(x)
    np.testing.assert_allclose(chained, full, rtol=1e-5, atol=1e-5)
    print("  self-check OK: cluster chain == full network")


def main(argv: list[str] | None = None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--outdir", default="../artifacts", help="artifact directory")
    ap.add_argument("--out", default=None,
                    help="(compat) path of the full-model stamp file")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--check", action="store_true", help="run the self-check only")
    args = ap.parse_args(argv)
    if args.check:
        self_check(args.seed)
        return
    outdir = pathlib.Path(args.out).parent if args.out else pathlib.Path(args.outdir)
    print(f"AOT: emitting artifacts into {outdir.resolve()}")
    build_artifacts(outdir, args.seed)
    print("AOT: done")


if __name__ == "__main__":
    main()
