"""AOT emitter correctness: HLO text is well-formed, manifest is consistent,
golden tensors round-trip, and emission is deterministic."""

from __future__ import annotations

import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, model

jax.config.update("jax_platform_name", "cpu")


@pytest.fixture(scope="module")
def built(tmp_path_factory):
    outdir = tmp_path_factory.mktemp("artifacts")
    manifest = aot.build_artifacts(outdir, seed=0)
    return outdir, manifest


def test_hlo_text_is_wellformed(built):
    outdir, manifest = built
    files = [c["file"] for c in manifest["clusters"]]
    files += [manifest["full"]["file"], manifest["micro"]["file"]]
    for entry in manifest["isp"]["layers"]:
        files += entry["files"]
    assert len(files) == len(set(files))
    for fname in files:
        text = (outdir / fname).read_text()
        assert "HloModule" in text, fname
        assert "ENTRY" in text, fname
        # return_tuple=True => root is a tuple
        assert "tuple(" in text or "(" in text.splitlines()[-2], fname


def test_params_metadata_consistent(built):
    outdir, manifest = built
    # every cluster: params file exists, sizes match shape products
    entries = list(manifest["clusters"]) + [manifest["full"]]
    for e in entries:
        pfile = outdir / e["params_file"]
        assert pfile.exists()
        total = sum(
            int(np.prod(p["shape"])) for p in e["params"]
        )
        assert pfile.stat().st_size == total * 4, e["params_file"]
    # conv cluster params come in (w, b) pairs
    c0 = manifest["clusters"][0]
    assert len(c0["params"]) == 4
    assert c0["params"][0]["shape"] == [3, 3, 3, 16]
    assert c0["params"][1]["shape"] == [16]


def test_isp_shard_params_split_cout(built):
    _, manifest = built
    ways = manifest["isp"]["ways"]
    for entry in manifest["isp"]["layers"]:
        assert len(entry["shard_params"]) == ways
        full_c = entry["full_output_shape"][-1]
        for sp in entry["shard_params"]:
            w_shape = sp["params"][0]["shape"]
            assert w_shape[-1] == full_c // ways


def test_weights_in_fn_matches_baked_fn():
    params = model.init_params(0)
    x = jax.random.normal(jax.random.PRNGKey(5), model.INPUT_SHAPE, jnp.float32)
    for idx in range(len(model.CLUSTERS)):
        fn, names = model.cluster_fn_weights_in(idx, use_pallas=False)
        want = model.cluster_fn(params, idx, use_pallas=False)(x)[0]
        got = fn(x, *[params[n] for n in names])[0]
        np.testing.assert_allclose(got, want, rtol=1e-6, atol=1e-6)
        x = want


def test_manifest_cluster_shapes_chain(built):
    _, manifest = built
    clusters = manifest["clusters"]
    assert len(clusters) == len(model.CLUSTERS)
    assert clusters[0]["input_shape"] == list(model.INPUT_SHAPE)
    for a, b in zip(clusters, clusters[1:]):
        assert a["output_shape"] == b["input_shape"]
    assert clusters[-1]["output_shape"] == [model.NUM_CLASSES]


def test_manifest_isp_entries(built):
    _, manifest = built
    isp = manifest["isp"]
    assert isp["ways"] == model.ISP_WAYS
    for entry in isp["layers"]:
        assert len(entry["files"]) == isp["ways"]
        shard_c = entry["shard_output_shape"][-1]
        assert entry["full_output_shape"][-1] == shard_c * isp["ways"]


def test_golden_tensors_roundtrip(built):
    outdir, manifest = built
    batch = manifest["golden_batch"]
    xs = np.fromfile(outdir / "golden_inputs.bin", dtype="<f4").reshape(
        batch, *model.INPUT_SHAPE
    )
    ys = np.fromfile(outdir / "golden_outputs.bin", dtype="<f4").reshape(
        batch, model.NUM_CLASSES
    )
    # Recompute one sample through the pallas path; must match the stored
    # reference-path outputs to kernel tolerance.
    params = model.init_params(manifest["seed"])
    (got,) = model.full_fn(params)(jnp.asarray(xs[0]))
    np.testing.assert_allclose(got, ys[0], rtol=1e-4, atol=1e-4)


def test_manifest_json_parses(built):
    outdir, _ = built
    manifest = json.loads((outdir / "manifest.json").read_text())
    assert manifest["num_classes"] == model.NUM_CLASSES


def test_emission_deterministic(tmp_path):
    a, b = tmp_path / "a", tmp_path / "b"
    aot.build_artifacts(a, seed=0)
    aot.build_artifacts(b, seed=0)
    for f in sorted(a.iterdir()):
        assert (b / f.name).read_bytes() == f.read_bytes(), f.name


def test_self_check_passes():
    aot.self_check(seed=0)
