"""L2 correctness: ScopeNet clusters compose, shards gather, shapes hold."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model

jax.config.update("jax_platform_name", "cpu")


@pytest.fixture(scope="module")
def params():
    return model.init_params(0)


@pytest.fixture(scope="module")
def sample():
    return jax.random.normal(jax.random.PRNGKey(99), model.INPUT_SHAPE, jnp.float32)


def test_init_params_deterministic():
    a, b = model.init_params(0), model.init_params(0)
    for k in a:
        np.testing.assert_array_equal(a[k], b[k])
    c = model.init_params(1)
    assert any(not np.array_equal(a[k], c[k]) for k in a)


def test_cluster_chain_equals_full_pallas(params, sample):
    x = sample
    for idx in range(len(model.CLUSTERS)):
        (x,) = model.cluster_fn(params, idx)(x)
    (full,) = model.full_fn(params)(sample)
    np.testing.assert_allclose(x, full, rtol=1e-5, atol=1e-5)


def test_pallas_path_matches_reference_path(params, sample):
    (got,) = model.full_fn(params, use_pallas=True)(sample)
    (want,) = model.full_fn(params, use_pallas=False)(sample)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_cluster_io_shapes_consistent(params, sample):
    shapes = model.cluster_io_shapes()
    assert shapes[0][0] == model.INPUT_SHAPE
    x = sample
    for idx, (in_shape, out_shape) in enumerate(shapes):
        assert tuple(x.shape) == in_shape
        (x,) = model.cluster_fn(params, idx)(x)
        assert tuple(x.shape) == out_shape
    assert shapes[-1][1] == (model.NUM_CLASSES,)
    # clusters must chain: each output feeds the next input
    for (_, out_s), (in_s, _) in zip(shapes, shapes[1:]):
        assert out_s == in_s


def test_isp_shards_gather_to_full_layer(params):
    # Run every ISP-emitted layer sharded and gathered; must equal unsharded.
    in_shape = model.cluster_io_shapes()[model.ISP_CLUSTER][0]
    x = jax.random.normal(jax.random.PRNGKey(3), in_shape, jnp.float32)
    for layer in model.CLUSTERS[model.ISP_CLUSTER]:
        if layer == "head":
            continue
        shards = [
            model.isp_shard_fn(params, layer, j)(x)[0]
            for j in range(model.ISP_WAYS)
        ]
        gathered = jnp.concatenate(shards, axis=-1)
        want = model.apply_conv(params, layer, x)
        np.testing.assert_allclose(gathered, want, rtol=1e-5, atol=1e-5)
        x = want  # feed next layer, as the coordinator does


def test_isp_shard_rejects_indivisible(params):
    with pytest.raises(ValueError):
        model.isp_shard_fn(params, "conv3", 0, ways=7)


def test_head_is_classifier_shaped(params, sample):
    (logits,) = model.full_fn(params)(sample)
    assert logits.shape == (model.NUM_CLASSES,)
    assert np.isfinite(np.asarray(logits)).all()
