"""L1 correctness: Pallas PE-array kernel vs the pure-jnp oracle.

This is the CORE correctness signal for the compiled artifacts: everything
the rust coordinator executes flows through ``matmul_pe``.  Hypothesis
sweeps shapes (including every tile-boundary edge case) and seeds.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import conv as kconv
from compile.kernels import matmul_pe as kmm
from compile.kernels import ref as kref

jax.config.update("jax_platform_name", "cpu")


def _rand(key, shape):
    return jax.random.normal(key, shape, jnp.float32)


def _split(seed, n):
    return jax.random.split(jax.random.PRNGKey(seed), n)


# ---------------------------------------------------------------------------
# matmul_pe vs matmul_ref
# ---------------------------------------------------------------------------


@settings(max_examples=25, deadline=None)
@given(
    m=st.integers(1, 40),
    k=st.integers(1, 40),
    n=st.integers(1, 150),
    seed=st.integers(0, 2**31 - 1),
)
def test_matmul_matches_ref_hypothesis(m, k, n, seed):
    kx, kw = _split(seed, 2)
    x, w = _rand(kx, (m, k)), _rand(kw, (k, n))
    got = kmm.matmul_pe(x, w)
    want = kref.matmul_ref(x, w)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize(
    "m,k,n",
    [
        (1, 1, 1),              # degenerate
        (8, 8, 128),            # exactly one tile
        (16, 16, 256),          # multiple tiles, no padding
        (9, 9, 129),            # one past every tile boundary
        (7, 7, 127),            # one short of every tile boundary
        (8, 27, 16),            # conv1-shaped reduction (3*3*3)
        (256, 144, 16),         # pixel-heavy, ScopeNet conv1 geometry
    ],
)
def test_matmul_tile_boundaries(m, k, n):
    kx, kw = _split(m * 1000 + k * 10 + n, 2)
    x, w = _rand(kx, (m, k)), _rand(kw, (k, n))
    np.testing.assert_allclose(
        kmm.matmul_pe(x, w), kref.matmul_ref(x, w), rtol=1e-5, atol=1e-5
    )


def test_matmul_nondefault_tiles():
    kx, kw = _split(3, 2)
    x, w = _rand(kx, (10, 20)), _rand(kw, (20, 30))
    got = kmm.matmul_pe(x, w, bm=4, bn=16, bk=4)
    np.testing.assert_allclose(got, kref.matmul_ref(x, w), rtol=1e-5, atol=1e-5)


def test_matmul_bias_relu_epilogue():
    kx, kw, kb = _split(11, 3)
    x, w, b = _rand(kx, (12, 24)), _rand(kw, (24, 48)), _rand(kb, (48,))
    got = kmm.matmul_pe_bias_act(x, w, b, relu=True)
    want = jnp.maximum(kref.matmul_ref(x, w) + b[None, :], 0.0)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)
    assert (np.asarray(got) >= 0).all()


def test_matmul_rejects_bad_shapes():
    x = jnp.zeros((4, 5))
    with pytest.raises(ValueError):
        kmm.matmul_pe(x, jnp.zeros((6, 7)))
    with pytest.raises(ValueError):
        kmm.matmul_pe(jnp.zeros((4,)), jnp.zeros((4, 4)))


def test_mxu_utilization_estimate_bounds():
    # Quantization estimate must be in (0, 1] and exact at tile multiples.
    assert kmm.mxu_utilization_estimate(8, 8, 128) == 1.0
    u = kmm.mxu_utilization_estimate(9, 9, 129)
    assert 0.0 < u < 0.5  # everything just past a boundary: heavy waste
    assert kmm.vmem_footprint_bytes() == 4 * (8 * 8 + 8 * 128 + 8 * 128)


# ---------------------------------------------------------------------------
# im2col + conv2d_pe vs lax conv
# ---------------------------------------------------------------------------


@settings(max_examples=20, deadline=None)
@given(
    h=st.integers(4, 12),
    w=st.integers(4, 12),
    cin=st.integers(1, 8),
    cout=st.integers(1, 20),
    k=st.sampled_from([1, 3, 5]),
    stride=st.sampled_from([1, 2]),
    seed=st.integers(0, 2**31 - 1),
)
def test_conv_matches_ref_hypothesis(h, w, cin, cout, k, stride, seed):
    pad = k // 2
    kx, kw_ = _split(seed, 2)
    x = _rand(kx, (h, w, cin))
    wt = _rand(kw_, (k, k, cin, cout))
    got = kconv.conv2d_pe(x, wt, stride=stride, pad=pad)
    want = kref.conv2d_ref(x, wt, stride=stride, pad=pad)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("stride,pad,k", [(1, 0, 1), (1, 1, 3), (2, 1, 3), (2, 2, 5)])
def test_conv_geometries(stride, pad, k):
    kx, kw_, kb = _split(stride * 100 + pad * 10 + k, 3)
    x = _rand(kx, (11, 9, 4))
    wt = _rand(kw_, (k, k, 4, 6))
    b = _rand(kb, (6,))
    got = kconv.conv2d_pe(x, wt, b, stride=stride, pad=pad, relu=True)
    want = kref.conv2d_ref(x, wt, b, stride=stride, pad=pad, relu=True)
    assert got.shape == want.shape
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_im2col_ordering_matches_weight_reshape():
    # The documented contract: im2col(x) @ w.reshape(-1, cout) == conv.
    kx, kw_ = _split(5, 2)
    x = _rand(kx, (6, 6, 3))
    wt = _rand(kw_, (3, 3, 3, 7))
    cols = kconv.im2col(x, 3, 3, stride=1, pad=1)
    assert cols.shape == (36, 27)
    got = (cols @ wt.reshape(27, 7)).reshape(6, 6, 7)
    np.testing.assert_allclose(
        got, kref.conv2d_ref(x, wt, stride=1, pad=1), rtol=1e-4, atol=1e-4
    )


def test_conv_rejects_bad_shapes():
    with pytest.raises(ValueError):
        kconv.conv2d_pe(jnp.zeros((4, 4, 3)), jnp.zeros((3, 3, 5, 8)))
    with pytest.raises(ValueError):
        kconv.im2col(jnp.zeros((4, 4)), 3, 3)


def test_out_size():
    assert kconv.out_size(16, 3, 1, 1) == 16
    assert kconv.out_size(16, 3, 2, 1) == 8
    assert kconv.out_size(7, 3, 2, 0) == 3
