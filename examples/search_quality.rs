//! Search-quality demo (Fig. 8 in miniature): exhaustively sweep a small
//! design space (ScopeNet on 8 chiplets by default) and show where the
//! Algorithm-1 result lands in the population — fast enough to run in
//! seconds, same machinery as the full AlexNet/16 bench.
//!
//! ```bash
//! cargo run --release --example search_quality [chiplets] [threads]
//! ```
//!
//! `threads` (0 = one worker per core, the default) fans both the
//! exhaustive sweep and Algorithm 1 across the deterministic worker pool —
//! the reported schedules are bit-identical at every thread count.

use anyhow::Result;

use scope::arch::McmConfig;
use scope::config::SimOptions;
use scope::dse::{exhaustive_segment, resolve_threads, ExhaustiveOptions};
use scope::model::zoo;
use scope::pipeline::timeline::EvalContext;
use scope::scope::{search_segment, SearchOptions};
use scope::storage::StoragePolicy;

fn main() -> Result<()> {
    let chiplets = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(8usize);
    let threads = std::env::args()
        .nth(2)
        .and_then(|s| s.parse().ok())
        .unwrap_or(0usize);
    let net = zoo::scopenet();
    let mcm = McmConfig::paper_default(chiplets);
    let opts = SimOptions { samples: 64, threads, ..Default::default() };
    let ctx = EvalContext {
        net: &net,
        mcm: &mcm,
        opts: &opts,
        policy: StoragePolicy::Distributed,
        dram_fallback: true,
    };

    println!(
        "exhaustive sweep: {} on {} chiplets ({} layers), {} worker threads…",
        net.name,
        chiplets,
        net.len(),
        resolve_threads(threads)
    );
    let t0 = std::time::Instant::now();
    let ex = exhaustive_segment(&ctx, 0, net.len(), 64, ExhaustiveOptions::default());
    println!(
        "  visited {} configs ({} valid) in {:.2}s; best = {:.0} cycles",
        ex.visited,
        ex.valid,
        t0.elapsed().as_secs_f64(),
        ex.best_latency
    );

    let t1 = std::time::Instant::now();
    let found = search_segment(&ctx, 0, net.len(), 64, SearchOptions::default())
        .expect("search result");
    println!(
        "  Algorithm 1: {:.0} cycles after {} Forward() calls in {:.3}s \
         (cluster cache: {} hits / {} misses)",
        found.latency,
        found.evals,
        t1.elapsed().as_secs_f64(),
        found.cache_hits,
        found.cache_misses
    );

    let rank = ex.rank_of(found.latency * (1.0 + 1e-9));
    println!(
        "\nrank of the searched schedule: top {:.3}% of {} valid schedules \
         (paper claims top 0.05% on AlexNet/16 — run `cargo bench --bench \
         fig8_search_quality` for that exact setting)",
        rank * 100.0,
        ex.valid
    );
    println!(
        "gap to exhaustive optimum: {:.2}%",
        (found.latency / ex.best_latency - 1.0) * 100.0
    );
    Ok(())
}
