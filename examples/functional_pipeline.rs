//! End-to-end driver (DESIGN.md E8): run the merged pipeline on *real
//! tensors* through all three layers of the stack —
//!
//!   L1 Pallas PE-array kernel → L2 JAX cluster modules (AOT HLO text) →
//!   L3 rust coordinator (threads = regions, bounded channels = NoP,
//!   PJRT CPU execution) —
//!
//! streaming a batch of samples through three topologies (single stage /
//! merged pipeline / merged + ISP-sharded cluster), validating every
//! output against the golden whole-network module, and reporting
//! latency + throughput. Recorded in EXPERIMENTS.md §E8.
//!
//! ```bash
//! make artifacts && cargo run --release --example functional_pipeline
//! ```

use anyhow::{ensure, Result};

use scope::bench::humanize_secs;
use scope::coordinator::{run_pipeline, PipelineMode};
use scope::runtime::Manifest;
use scope::util::table::{f3, Table};

fn main() -> Result<()> {
    let dir = Manifest::default_dir();
    let manifest = Manifest::load(&dir)?;
    println!(
        "artifacts: {} ({} clusters, input {:?}, {} classes)\n",
        dir.display(),
        manifest.clusters.len(),
        manifest.input_shape,
        manifest.num_classes
    );

    let samples = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(48usize);

    let mut t = Table::new(
        &format!("functional merged pipeline — {samples} samples (PJRT CPU)"),
        &["mode", "stages", "samples/s", "mean latency", "max |err|", "numerics"],
    );
    let mut merged_tp = 0.0;
    let mut single_tp = 0.0;
    for mode in [PipelineMode::Single, PipelineMode::Merged, PipelineMode::MergedIsp] {
        let r = run_pipeline(&manifest, mode, samples)?;
        ensure!(
            r.numerics_ok(1e-3),
            "{}: outputs diverged from golden ({})",
            r.mode,
            r.max_abs_err
        );
        match mode {
            PipelineMode::Merged => merged_tp = r.throughput(),
            PipelineMode::Single => single_tp = r.throughput(),
            _ => {}
        }
        t.row(vec![
            r.mode.clone(),
            r.stages.to_string(),
            f3(r.throughput()),
            humanize_secs(r.mean_latency()),
            format!("{:.2e}", r.max_abs_err),
            "OK".into(),
        ]);
    }
    println!("{t}");
    println!(
        "\npipeline speedup (merged vs single stage): {:.2}x — \
         the merged pipeline overlaps cluster stages exactly as Equ. 2 models",
        merged_tp / single_tp
    );
    println!("all outputs match the golden whole-network module — L1/L2/L3 compose.");
    Ok(())
}
