//! The paper's §V-B(d) case study: ResNet-152 on a 256-chiplet MCM —
//! Scope's merged clusters vs the segmented pipeline's per-layer stages.
//!
//! Reproduces both panels of Fig. 10: (a) normalized per-stage compute
//! balance (Scope: fewer segments, lower variance → easier stage
//! matching), (b) the energy breakdown (roughly equivalent totals — the
//! win is utilization, not energy).
//!
//! ```bash
//! cargo run --release --example casestudy_resnet152 [chiplets]
//! ```

use anyhow::Result;

use scope::report::figures;
use scope::util::table::f3;

fn main() -> Result<()> {
    let chiplets = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(256usize);
    println!("case study: resnet152 on {chiplets} chiplets (paper Fig. 10)\n");
    let r = figures::fig10("resnet152", chiplets, 64)?;
    println!("{}", r.balance);
    println!();
    println!("{}", r.energy);
    println!();
    println!(
        "segments: scope={} vs segmented={} (paper: 2 vs 3)",
        r.scope_segments, r.segmented_segments
    );
    println!(
        "compute-balance CV: scope={} vs segmented={} — \
         merging yields the flatter stage profile of Fig. 10a",
        f3(r.scope_cv),
        f3(r.segmented_cv)
    );
    Ok(())
}
