//! Quickstart: schedule a network on an MCM with Scope and compare against
//! the three baselines — the 60-second tour of the public API.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```
//!
//! A doc-tested twin of this walkthrough lives in the crate-level rustdoc
//! (`rust/src/lib.rs`) — `cargo test` executes it, so the tour can never
//! drift from the API. Further doc-tested entry points: `DagBuilder`
//! (`model/dag.rs`), `search_segments_dag` (`scope/dag_segment.rs`), and
//! the multi-model co-scheduler (`scope/multi_model.rs`).

use anyhow::Result;

use scope::arch::McmConfig;
use scope::baselines::run_all;
use scope::config::SimOptions;
use scope::model::zoo;
use scope::util::table::{f3, Table};

fn main() -> Result<()> {
    // 1. Pick a workload from the zoo and a package scale (Table III
    //    platform at 64 chiplets). `SimOptions::threads` controls the DSE
    //    worker pool (0 = one per core; the CLI exposes it as --threads);
    //    the search result is bit-identical at every thread count.
    let net = zoo::resnet18();
    let mcm = McmConfig::paper_default(64);
    let opts = SimOptions { samples: 64, ..Default::default() };
    println!(
        "workload: {} ({} layers, {:.1} GMACs, {:.1} MB weights)",
        net.name,
        net.len(),
        net.total_macs() as f64 / 1e9,
        net.total_weight_bytes() as f64 / 1e6
    );
    println!(
        "platform: {} chiplets ({}x{} mesh), {:.0} GMAC/s/chiplet peak\n",
        mcm.chiplets,
        mcm.mesh.width,
        mcm.mesh.height,
        mcm.chiplet.peak_macs_per_sec() / 1e9
    );

    // 2. Run all four schedulers (sequential, full pipeline, segmented,
    //    Scope) through the same cost model.
    let results = run_all(&net, &mcm, &opts);
    let best = results.iter().map(|r| r.throughput()).fold(0.0, f64::max);
    let mut t = Table::new(
        "methods",
        &["method", "samples/s", "normalized", "J/batch"],
    );
    for r in &results {
        t.row(vec![
            r.method.clone(),
            if r.eval.is_valid() { f3(r.throughput()) } else { "invalid".into() },
            if r.eval.is_valid() { f3(r.throughput() / best) } else { "-".into() },
            if r.eval.is_valid() {
                f3(r.eval.energy.total_pj() * 1e-12)
            } else {
                "-".into()
            },
        ]);
    }
    println!("{t}\n");

    // 3. Inspect the Scope schedule itself: merged clusters, regions,
    //    WSP→ISP partitions.
    let scope_result = results.last().unwrap();
    if let Some(sched) = &scope_result.schedule {
        for (si, seg) in sched.segments.iter().enumerate() {
            print!("segment {si}: ");
            for j in 0..seg.n_clusters() {
                let (lo, hi) = seg.cluster_range(j);
                print!("[{}L×{}c] ", hi - lo, seg.regions[j]);
            }
            println!();
        }
        println!(
            "\n{} clusters over {} layers — merged pipeline in action",
            sched.total_clusters(),
            net.len()
        );
    }
    Ok(())
}
